"""Pluggable transports: the link between DEFER runtime entities.

Every hop in the serving topology — pump -> stage router, router -> replica
inbox, replica egress -> next stage, last stage -> collector — is a
:class:`Channel` obtained from a :class:`Transport`.  The wire *format*
(:class:`~repro.runtime.wire.BatchEnvelope` framing plus the
:func:`~repro.runtime.wire.frame`/:func:`~repro.runtime.wire.unframe`
channel-item envelope) is transport-agnostic; a transport only moves
already-encoded items between endpoints, so stage specs select a backend
by name (:class:`~repro.runtime.topology.StageSpec.transport`) without
touching the codec or batching layers.

Three backends ship in-tree:

* ``"inproc"`` — a bounded thread-safe queue, the default.  Exactly the
  structure the chain used before transports existed, so the staged-relay
  backpressure semantics (a full channel blocks the sender) are unchanged.
* ``"tcp"`` — real loopback/LAN sockets (:class:`TcpTransport`): one
  listener + connection pool per transport instance, every channel item
  framed to bytes (:func:`~repro.runtime.wire.frame`, length-prefixed on
  the stream, no pickle), and a credit window so ``send`` blocks at
  ``capacity`` outstanding items — the kernel socket buffer cannot silently
  widen the staged-relay backpressure contract.  ``qsize`` is the
  outstanding-credit count, so least-queue-depth routing keeps working.
* ``"link:<bw>,<latency>[,<jitter>]"`` — :class:`LinkTransport`, the
  paper's CORE-emulated Ethernet without privileges: items are framed to
  bytes and delivery is shaped by a serialization delay (``bytes / bw``),
  a propagation latency, and optional uniform jitter (FIFO preserved by a
  monotonic-ready clamp, like TCP ordering under CORE).  E.g.
  ``"link:10mbit,20ms"`` or ``"link:1gbit,2ms,1ms"``; bare ``"link"`` is
  100 Mbit / 5 ms (the paper's Ethernet).

``recv_nowait``/``recv(timeout=)`` raise :class:`queue.Empty`, mirroring
the stdlib so the node stage loops keep their idioms.  A channel whose
peer vanished (socket reset, :meth:`Channel.kill`) raises
:class:`ChannelClosed` from ``send``/``recv`` — the runtime turns that
into a per-batch failure plus a self-retiring replica instead of a hang.

New backends register with :func:`register_transport` (a plain name) or
:func:`register_transport_scheme` (a ``scheme:args`` family like
``link:``).  Re-registering a name whose live instance still backs
channels is refused — a live engine would otherwise keep sending into a
transport the registry no longer knows — until those channels are closed
(``Dispatcher.shutdown`` closes every channel it opened) or the caller
passes ``force=True``.
"""
from __future__ import annotations

import queue
import random
import socket
import struct
import threading
import time
from collections import deque
from typing import Any, Callable

from repro.runtime import wire as _wire

Empty = queue.Empty


class ChannelClosed(Exception):
    """The channel's peer is gone (socket reset / killed link): sends and
    recvs can never complete.  Distinct from :class:`queue.Empty` so the
    node stage loops can tell "nothing yet" from "never again"."""


class Channel:
    """One directed edge between runtime entities.

    ``send`` blocks when the channel is at capacity (backpressure is the
    runtime's flow control); ``recv`` blocks until an item arrives.  Items
    are opaque to the channel: envelopes, fence markers, and the stop
    token all ride the same FIFO, which is what makes the epoch fence
    ordering argument work on any transport that preserves per-channel
    FIFO delivery.
    """

    def send(self, item: Any) -> None:
        raise NotImplementedError

    def recv(self, timeout: float | None = None) -> Any:
        raise NotImplementedError

    def recv_nowait(self) -> Any:
        raise NotImplementedError

    def qsize(self) -> int:
        """Queued-item count, used as the least-queue-depth routing signal.
        Backends without cheap introspection keep this default: 0 for
        every channel makes lqd degrade gracefully to round-robin."""
        return 0

    @property
    def dead(self) -> bool:
        """True once the channel can never carry another item (killed, or
        the peer endpoint is known gone).  Routers probe this so a member
        whose process died is healed even while no send is in flight —
        without it, stranded batches would wait for the next send to that
        member, which under least-queue-depth routing may never come.
        Backends without liveness knowledge keep the default False."""
        return False

    def close(self) -> None:
        """Release the channel's resources and drop it from its owning
        transport's live count (see :func:`register_transport`).  Safe to
        call twice; the base implementation only does the bookkeeping."""
        tr = getattr(self, "_owner", None)
        if tr is not None and not getattr(self, "_untracked", False):
            self._untracked = True
            tr._live_channels = max(0, tr.live_channels - 1)


class InprocChannel(Channel):
    """The default transport's channel: a bounded in-process queue."""

    def __init__(self, capacity: int = 0):
        self._q: queue.Queue = queue.Queue(maxsize=capacity)

    def send(self, item: Any) -> None:
        self._q.put(item)

    def recv(self, timeout: float | None = None) -> Any:
        return self._q.get(timeout=timeout)

    def recv_nowait(self) -> Any:
        return self._q.get_nowait()

    def qsize(self) -> int:
        return self._q.qsize()


class Transport:
    """A channel factory.  Subclasses back channels with a different
    medium (sockets, an emulated lossy/slow link, shared memory).

    Backends that call :meth:`_track` on the channels they hand out get
    live-channel accounting for free: :func:`register_transport` refuses
    to replace an instance that still backs open channels.  Backends that
    skip it degrade gracefully (``live_channels`` stays 0)."""

    name = "abstract"

    def channel(self, capacity: int = 0) -> Channel:
        raise NotImplementedError

    @property
    def live_channels(self) -> int:
        return getattr(self, "_live_channels", 0)

    def _track(self, ch: Channel) -> Channel:
        self._live_channels = self.live_channels + 1
        ch._owner = self
        return ch


class InprocTransport(Transport):
    name = "inproc"

    def channel(self, capacity: int = 0) -> Channel:
        return self._track(InprocChannel(capacity))


# -- TCP sockets ---------------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise ConnectionError on EOF."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise ConnectionError("socket closed mid-frame")
        got += k
    return bytes(buf)


def _recv_u32(sock: socket.socket, what: str) -> int:
    """Read one little-endian u32 header field through the wire bounds
    gate, so a truncated or malformed peer surfaces as WireFormatError
    (the per-channel fault the read loops already translate into a clean
    channel death) instead of a bare struct.error."""
    try:
        buf = _recv_exact(sock, 4)
    except ConnectionError as e:
        raise _wire.WireFormatError(f"truncated {what}: {e}") from e
    _wire._checked(buf, 0, 4, what)
    (v,) = struct.unpack("<I", buf)
    return v


_CLOSED = object()      # reader-thread sentinel: the stream is gone


class _CreditWindow:
    """Bounded-in-flight accounting shared by the byte transports: at
    most ``capacity`` unconsumed sends may be outstanding (0 =
    unbounded), and ``outstanding()`` is the depth signal ``qsize``
    reports.  One implementation so the backpressure invariant — and its
    kill/rollback edge cases — cannot drift between backends."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._sem = threading.Semaphore(capacity) if capacity > 0 else None
        self._outstanding = 0
        self._lock = threading.Lock()

    def take(self, is_killed) -> None:
        """Block for a credit, then count one outstanding item.  Raises
        :class:`ChannelClosed` if the channel died while blocked (kill
        floods the semaphore so blocked senders wake)."""
        if self._sem is not None:
            self._sem.acquire()
            if is_killed():
                self._sem.release()
                raise ChannelClosed("channel was killed")
        with self._lock:
            self._outstanding += 1

    def untake(self) -> None:
        """Roll back a take whose send failed."""
        with self._lock:
            self._outstanding -= 1
        if self._sem is not None:
            self._sem.release()

    def consumed(self) -> None:
        """One item left the window (receiver consumed it)."""
        with self._lock:
            self._outstanding -= 1
        if self._sem is not None:
            self._sem.release()

    def outstanding(self) -> int:
        with self._lock:
            return self._outstanding

    def flood(self) -> None:
        """Open the window wide so senders blocked on a credit that will
        never come wake up and see the kill flag."""
        if self._sem is not None:
            for _ in range(self.capacity + 1):
                self._sem.release()


class TcpChannel(Channel):
    """One TCP connection carrying framed channel items one way and
    credit bytes the other way.

    The sender may not outrun the consumer: with ``capacity > 0`` each
    ``send`` takes a credit and each *consumed* ``recv`` returns one (a
    single byte on the reverse half of the connection), so at most
    ``capacity`` items are in flight across the socket buffer and the
    receive queue combined — the staged-relay backpressure contract,
    independent of kernel buffer sizing.  ``qsize`` reports the
    outstanding (sent-but-unconsumed) count, which is exactly the depth
    signal lqd routing wants."""

    def __init__(self, capacity: int = 0):
        self.capacity = capacity
        self._window = _CreditWindow(capacity)
        self._send_lock = threading.Lock()
        self._recv_q: queue.Queue = queue.Queue()
        self._send_sock: socket.socket | None = None
        self._recv_sock: socket.socket | None = None
        self._attached = threading.Event()
        self._killed = False
        self._peer_lost = False

    # -- wiring (transport-internal) ------------------------------------------
    def _open_send_side(self, sock: socket.socket) -> None:
        self._send_sock = sock
        threading.Thread(target=self._credit_loop, daemon=True).start()

    def _attach(self, conn: socket.socket) -> None:
        self._recv_sock = conn
        threading.Thread(target=self._read_loop, daemon=True).start()
        self._attached.set()

    def _credit_loop(self) -> None:
        sock = self._send_sock
        try:
            while True:
                b = sock.recv(4096)
                if not b:
                    return
                for _ in range(len(b)):
                    self._window.consumed()
        except OSError:
            return
        finally:
            # a dead credit stream would block senders forever: flood the
            # window open so their next send hits the socket error instead
            self._peer_lost = True
            self._window.flood()

    def _read_loop(self) -> None:
        sock = self._recv_sock
        try:
            while True:
                ln = _recv_u32(sock, "tcp frame length prefix")
                self._recv_q.put(_wire.unframe(_recv_exact(sock, ln)))
        except (OSError, ConnectionError, _wire.WireFormatError):
            # EOF, reset, or an unrecoverable framing desync: the stream
            # cannot be resynchronized, so the channel is dead
            self._peer_lost = True
            self._recv_q.put(_CLOSED)

    # -- Channel API ----------------------------------------------------------
    def wait_attached(self, timeout: float = 10.0) -> None:
        """Block until the peer wires this half (expect_channel halves
        are exposed before their remote peer dials in)."""
        if not self._attached.wait(timeout):
            raise ChannelClosed(
                f"tcp half-channel peer never attached within {timeout}s")

    def send(self, item: Any) -> None:
        if self._killed:
            raise ChannelClosed("tcp channel was killed")
        if not self._attached.is_set():
            # an expect_channel send half raced its peer's dial: the
            # accept loop wires it asynchronously, so wait instead of
            # tripping over a not-yet-assigned socket
            self.wait_attached()
            if self._killed:
                raise ChannelClosed("tcp channel was killed")
        blob = _wire.frame(item)
        if len(blob) >= 1 << 32:
            # validated BEFORE any credit accounting so an oversized
            # payload is a clean per-item error, not a leaked credit
            raise _wire.WireFormatError(
                f"frame of {len(blob)} bytes exceeds the 4-byte length "
                "prefix (max 4 GiB per channel item)")
        self._window.take(lambda: self._killed)
        try:
            with self._send_lock:
                if len(blob) <= 64 * 1024:
                    # small frame: one packet, the copy is cheap
                    self._send_sock.sendall(
                        struct.pack("<I", len(blob)) + blob)
                else:
                    # big frame: two sendalls instead of re-copying a
                    # multi-MB payload just to prepend 4 bytes
                    self._send_sock.sendall(struct.pack("<I", len(blob)))
                    self._send_sock.sendall(blob)
        except (OSError, AttributeError) as e:
            self._window.untake()
            raise ChannelClosed(f"tcp send failed: {e}") from e

    def _take(self, item: Any) -> Any:
        if item is _CLOSED:
            self._recv_q.put(_CLOSED)       # keep raising for later recvs
            raise ChannelClosed("tcp channel closed by peer")
        try:
            self._recv_sock.sendall(b"\x01")    # return one credit
        except OSError:
            pass                            # sender gone; item still valid
        return item

    def recv(self, timeout: float | None = None) -> Any:
        return self._take(self._recv_q.get(timeout=timeout))

    def recv_nowait(self) -> Any:
        return self._take(self._recv_q.get_nowait())

    def qsize(self) -> int:
        return self._window.outstanding()

    @property
    def dead(self) -> bool:
        return self._killed or self._peer_lost

    def kill(self) -> None:
        """Sever the connection as a network failure would: both socket
        halves close, in-flight frames are lost, the next ``send`` raises
        :class:`ChannelClosed` and blocked ``recv`` callers wake with the
        same — the failure-injection hook the kill-the-socket tests use."""
        self._killed = True
        self._attached.set()        # unblock senders waiting on a peer
        for s in (self._send_sock, self._recv_sock):    # that never dials
            if s is not None:
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                try:
                    s.close()
                except OSError:
                    pass
        self._window.flood()            # wake senders blocked on a credit
        self._recv_q.put(_CLOSED)       # that will never come

    def close(self) -> None:
        self.kill()
        super().close()


class TcpTransport(Transport):
    """Real sockets on loopback (or a LAN host): one listening socket per
    transport instance, one pooled connection per channel, channel items
    length-prefix framed on the stream (:func:`~repro.runtime.wire.frame`,
    no pickle).  The listener binds lazily on the first ``channel()``
    call, so merely *validating* a spec that names ``"tcp"`` opens no
    sockets."""

    name = "tcp"

    # a connection that sends a partial hello then stalls would otherwise
    # pin the single accept thread forever (half-open handshake): the
    # hello read runs under this socket timeout and a stalled client is
    # dropped, after which the accept loop serves the next connection
    handshake_timeout_s = 5.0

    def __init__(self, host: str = "127.0.0.1"):
        self._host = host
        self._listener: socket.socket | None = None
        self._pending: dict[int, TcpChannel] = {}
        self._roles: dict[int, str] = {}    # cid -> local half ("send"/"recv")
        self._next_cid = 0
        self._lock = threading.Lock()

    @property
    def address(self) -> tuple[str, int] | None:
        """(host, port) of the listener, once bound."""
        return (self._listener.getsockname() if self._listener is not None
                else None)

    def _ensure_listener(self) -> None:
        with self._lock:
            if self._listener is not None:
                return
            s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            try:
                s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
                s.bind((self._host, 0))
                s.listen(128)
            except BaseException:
                s.close()
                raise
            self._listener = s
            threading.Thread(target=self._accept_loop, daemon=True).start()

    def _accept_loop(self) -> None:
        while True:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            try:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                # the 4-byte hello names the channel this connection backs.
                # socket.timeout is an OSError, so a half-open client that
                # stalls mid-hello lands here and is dropped
                conn.settimeout(self.handshake_timeout_s)
                cid = _recv_u32(conn, "tcp channel hello")
                conn.settimeout(None)       # read loops expect blocking IO
            except (OSError, ConnectionError, _wire.WireFormatError):
                conn.close()
                continue
            with self._lock:
                ch = self._pending.pop(cid, None)
                role = self._roles.pop(cid, "recv")
            if ch is None:
                conn.close()
                continue
            if role == "send":
                # a half-channel registered by expect_channel(role="send"):
                # this side only transmits, the dialing peer receives
                ch._open_send_side(conn)
                ch._attached.set()
            else:
                ch._attach(conn)

    def channel(self, capacity: int = 0) -> Channel:
        self._ensure_listener()
        ch = TcpChannel(capacity)
        with self._lock:
            cid = self._next_cid
            self._next_cid += 1
            self._pending[cid] = ch
        sock = None
        try:
            sock = socket.create_connection(self.address, timeout=10.0)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sock.sendall(struct.pack("<I", cid))
            ch._open_send_side(sock)
            if not ch._attached.wait(10.0):
                raise ChannelClosed("tcp accept timed out")
        except Exception as e:
            # failed mid-handshake: un-register the pending slot (a late
            # accept must not wire a conn onto a discarded channel) and
            # close the socket (which also ends its credit thread)
            with self._lock:
                self._pending.pop(cid, None)
            if sock is not None:
                try:
                    sock.close()
                except OSError:
                    pass
            if isinstance(e, ChannelClosed):
                raise
            raise ChannelClosed(f"tcp channel setup failed: {e}") from e
        return self._track(ch)

    def expect_channel(self, capacity: int = 0,
                       role: str = "send") -> tuple[TcpChannel, int]:
        """Register a cross-process half-channel and return ``(channel,
        cid)``.  A remote peer completes it by dialing this transport's
        listener and sending ``cid`` as the hello
        (:func:`dial_channel`); until then the local half is unattached
        (``wait_attached``).  ``role`` names the LOCAL half: ``"send"``
        (this process transmits, the peer receives — e.g. a worker's
        inbox held by the supervisor) or ``"recv"`` (the peer transmits
        into this process — e.g. a worker's output stream).  Unlike
        :meth:`channel`, nothing dials back: the peer only ever connects
        *in*, so workers never need a listener of their own."""
        if role not in ("send", "recv"):
            raise ValueError(f"bad channel role {role!r}")
        self._ensure_listener()
        ch = TcpChannel(capacity)
        with self._lock:
            cid = self._next_cid
            self._next_cid += 1
            self._pending[cid] = ch
            self._roles[cid] = role
        return self._track(ch), cid

    def unexpect_channel(self, cid: int) -> None:
        """Drop a pending expect_channel registration whose peer never
        arrived (spawn failure cleanup): a late dial with this cid then
        meets a closed connection instead of wiring a discarded channel."""
        with self._lock:
            self._pending.pop(cid, None)
            self._roles.pop(cid, None)

    def close(self) -> None:
        """Close the listener socket (the accept thread exits).  Already
        wired channels keep their pooled connections; pending
        expect_channel halves can no longer be completed.  For private
        transport instances (e.g. a supervisor's data plane) — the shared
        registry instance from :func:`get_transport` should outlive any
        one engine."""
        with self._lock:
            listener = self._listener
        if listener is not None:
            try:
                listener.close()
            except OSError:
                pass


def dial_channel(host: str, port: int, cid: int, role: str,
                 capacity: int = 0, timeout: float = 10.0) -> TcpChannel:
    """Complete a half-channel a remote :meth:`TcpTransport.expect_channel`
    registered: connect to its listener, send the cid hello, and wire the
    LOCAL half (``role``: ``"send"`` or ``"recv"`` — the opposite of what
    the registering side chose).  The worker-side entry point for
    cross-process channels."""
    if role not in ("send", "recv"):
        raise ValueError(f"bad channel role {role!r}")
    # build the channel before connecting: once the socket exists, every
    # remaining step either hands it off or closes it
    ch = TcpChannel(capacity)
    sock = socket.create_connection((host, port), timeout=timeout)
    try:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.sendall(struct.pack("<I", cid))
        sock.settimeout(None)               # read loops expect blocking IO
    except OSError as e:
        try:
            sock.close()
        except OSError:
            pass
        raise ChannelClosed(f"tcp dial failed: {e}") from e
    if role == "send":
        ch._open_send_side(sock)
        ch._attached.set()
    else:
        ch._attach(sock)
    return ch


# -- framed control streams (supervisor <-> worker sideband) -------------------

def send_framed(sock: socket.socket, item: Any,
                lock: threading.Lock | None = None) -> None:
    """Write one channel item onto a raw socket with the same
    ``[u32 length][wire.frame bytes]`` layout the TCP channels speak.
    Used by the supervisor/worker control sockets, which carry
    :class:`~repro.runtime.wire.ControlFrame` heartbeats and the initial
    :class:`~repro.runtime.wire.ReconfigMarker` config+weights handoff
    without the credit-window machinery (control traffic is tiny and
    strictly request/reply or periodic)."""
    blob = _wire.frame(item)
    if len(blob) >= 1 << 32:
        raise _wire.WireFormatError(
            f"control frame of {len(blob)} bytes exceeds the 4-byte "
            "length prefix")
    payload = struct.pack("<I", len(blob)) + blob
    if lock is not None:
        with lock:
            sock.sendall(payload)
    else:
        sock.sendall(payload)


def recv_framed(sock: socket.socket) -> Any:
    """Read one ``[u32 length][wire.frame bytes]`` item from a raw socket
    (blocking; honors the socket's own timeout).  EOF or truncation raise
    :class:`~repro.runtime.wire.WireFormatError` like every other wire
    read."""
    ln = _recv_u32(sock, "control frame length prefix")
    return _wire.unframe(_recv_exact(sock, ln))


# -- emulated link (the paper's CORE conditions, unprivileged) -----------------

_UNITS = {"bit": 1 / 8, "kbit": 125.0, "mbit": 125e3, "gbit": 125e6,
          "kbps": 125.0, "mbps": 125e3, "gbps": 125e6,
          "b": 1.0, "kb": 1e3, "mb": 1e6, "gb": 1e9}


def _parse_rate(tok: str) -> float:
    """'10mbit' -> bytes/second."""
    tok = tok.strip().lower()
    for unit in sorted(_UNITS, key=len, reverse=True):
        if tok.endswith(unit):
            try:
                return float(tok[: -len(unit)]) * _UNITS[unit]
            except ValueError:
                break
    raise ValueError(f"bad link bandwidth {tok!r} "
                     f"(want e.g. '10mbit', '1gbit', '500kbit')")


def _parse_time(tok: str) -> float:
    """'20ms' / '0.1s' / '150us' -> seconds."""
    tok = tok.strip().lower()
    for unit, mult in (("us", 1e-6), ("ms", 1e-3), ("s", 1.0)):
        if tok.endswith(unit):
            try:
                return float(tok[: -len(unit)]) * mult
            except ValueError:
                break
    raise ValueError(f"bad link time {tok!r} (want e.g. '20ms', '0.5s')")


class LinkChannel(Channel):
    """An in-process channel shaped like an emulated network link.

    Items are framed to bytes (the same no-pickle wire the TCP backend
    speaks), then delivery is shaped: a transmitter thread holds each
    frame for ``bytes / bandwidth`` seconds (serialization delay — the
    link is busy, so back-to-back frames queue behind each other exactly
    as on a real NIC), after which the item becomes receivable
    ``latency + U(0, jitter)`` later.  Ready times are clamped monotonic
    so jitter never reorders a FIFO stream (as TCP under CORE).  The
    credit window mirrors the TCP backend: at most ``capacity`` items in
    flight, ``qsize`` = outstanding."""

    def __init__(self, capacity: int, bandwidth_bytes_s: float,
                 latency_s: float, jitter_s: float, seed: int = 0):
        self.capacity = capacity
        self._bw = max(1.0, float(bandwidth_bytes_s))
        self._lat = max(0.0, float(latency_s))
        self._jit = max(0.0, float(jitter_s))
        self._rng = random.Random(seed)
        self._window = _CreditWindow(capacity)
        self._pending: queue.Queue = queue.Queue()
        self._cond = threading.Condition()
        self._ready: deque = deque()        # (ready_at, item), ready_at asc
        self._last_ready = 0.0
        self._killed = False
        threading.Thread(target=self._xmit_loop, daemon=True).start()

    def _xmit_loop(self) -> None:
        while True:
            blob = self._pending.get()
            if blob is _CLOSED:
                with self._cond:
                    self._cond.notify_all()
                return
            time.sleep(len(blob) / self._bw)        # link occupied
            delay = self._lat + (self._rng.uniform(0.0, self._jit)
                                 if self._jit else 0.0)
            item = _wire.unframe(blob)
            with self._cond:
                ready = max(time.monotonic() + delay, self._last_ready)
                self._last_ready = ready
                self._ready.append((ready, item))
                self._cond.notify_all()

    def send(self, item: Any) -> None:
        if self._killed:
            raise ChannelClosed("link channel was killed")
        blob = _wire.frame(item)
        self._window.take(lambda: self._killed)
        self._pending.put(blob)

    def _pop_ready_locked(self) -> Any:
        _, item = self._ready.popleft()
        self._window.consumed()
        return item

    def recv(self, timeout: float | None = None) -> Any:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                now = time.monotonic()
                if self._ready and self._ready[0][0] <= now:
                    return self._pop_ready_locked()
                if self._killed and not self._ready:
                    raise ChannelClosed("link channel was killed")
                waits = []
                if self._ready:
                    waits.append(self._ready[0][0] - now)
                if deadline is not None:
                    if now >= deadline:
                        raise queue.Empty
                    waits.append(deadline - now)
                self._cond.wait(min(waits) if waits else None)

    def recv_nowait(self) -> Any:
        with self._cond:
            if self._ready and self._ready[0][0] <= time.monotonic():
                return self._pop_ready_locked()
            if self._killed and not self._ready:
                raise ChannelClosed("link channel was killed")
            raise queue.Empty

    def qsize(self) -> int:
        return self._window.outstanding()

    def kill(self) -> None:
        self._killed = True
        self._pending.put(_CLOSED)
        self._window.flood()
        with self._cond:
            self._cond.notify_all()

    def close(self) -> None:
        self.kill()
        super().close()


class LinkTransport(Transport):
    """Channels shaped by a configurable bandwidth / latency / jitter —
    the paper's CORE-emulated Ethernet reproduced without privileges.
    Registered bare as ``"link"`` (100 Mbit, 5 ms — the paper's links)
    and as the ``link:`` scheme: ``"link:10mbit,20ms"``,
    ``"link:1gbit,2ms,1ms"``."""

    name = "link"

    def __init__(self, bandwidth_bytes_s: float = 12.5e6,
                 latency_s: float = 0.005, jitter_s: float = 0.0,
                 seed: int = 0):
        self.bandwidth_bytes_s = float(bandwidth_bytes_s)
        self.latency_s = float(latency_s)
        self.jitter_s = float(jitter_s)
        self._seed = seed
        self._made = 0

    @classmethod
    def from_spec(cls, spec: str) -> "LinkTransport":
        """Parse '<bw>,<latency>[,<jitter>]' (the ``link:`` scheme args)."""
        parts = [p for p in spec.split(",") if p.strip()]
        if not 1 <= len(parts) <= 3:
            raise ValueError(
                f"bad link spec {spec!r} (want 'bw,latency[,jitter]', "
                "e.g. '10mbit,20ms' or '1gbit,2ms,1ms')")
        bw = _parse_rate(parts[0])
        lat = _parse_time(parts[1]) if len(parts) > 1 else 0.0
        jit = _parse_time(parts[2]) if len(parts) > 2 else 0.0
        return cls(bw, lat, jit)

    def channel(self, capacity: int = 0) -> Channel:
        self._made += 1
        return self._track(LinkChannel(
            capacity, self.bandwidth_bytes_s, self.latency_s, self.jitter_s,
            seed=self._seed + self._made))


# -- registry ------------------------------------------------------------------

_TRANSPORTS: dict[str, Callable[[], Transport]] = {
    "inproc": InprocTransport,
    "tcp": TcpTransport,
    "link": LinkTransport,
}
# scheme factories: "scheme:args" names resolve through these when the
# full name has no direct registration; each distinct full name still
# gets (and caches) its own shared instance
_SCHEMES: dict[str, Callable[[str], Transport]] = {
    "link": LinkTransport.from_spec,
}
_INSTANCES: dict[str, Transport] = {}


def register_transport(name: str, factory: Callable[[], Transport],
                       force: bool = False) -> None:
    """Make ``name`` usable as a :class:`StageSpec.transport` binding.

    Re-registering a name whose shared instance still backs live channels
    is refused: a running engine holds those channels, and silently
    swapping the instance out from under it would strand them (new
    channels on the new instance, old ones on an orphan).  Close the
    channels first (``Dispatcher.shutdown`` does) or pass ``force=True``
    to strand them knowingly."""
    inst = _INSTANCES.get(name)
    if inst is not None and inst.live_channels > 0 and not force:
        raise ValueError(
            f"transport {name!r} still backs {inst.live_channels} live "
            "channel(s) — re-registering would strand them; shut down the "
            "engine(s) using it (or pass force=True)")
    _TRANSPORTS[name] = factory
    _INSTANCES.pop(name, None)          # a re-registration replaces state


def register_transport_scheme(scheme: str,
                              factory: Callable[[str], Transport],
                              force: bool = False) -> None:
    """Register a parameterized transport family: any binding of the form
    ``"<scheme>:<args>"`` resolves through ``factory(args)``, one shared
    instance per distinct full name (so ``"link:10mbit,20ms"`` and
    ``"link:1gbit,1ms"`` are two independent links).

    Same strand protection as :func:`register_transport`, applied to
    every cached instance of the scheme: re-registration is refused
    while any such instance backs live channels (unless ``force``), and
    the stale cached instances are dropped so the new factory actually
    takes effect for already-resolved full names."""
    cached = [n for n in _INSTANCES if n.partition(":")[0] == scheme
              and n not in _TRANSPORTS]
    live = {n: _INSTANCES[n].live_channels for n in cached
            if _INSTANCES[n].live_channels > 0}
    if live and not force:
        raise ValueError(
            f"transport scheme {scheme!r} still backs live channels via "
            f"{sorted(live)} — re-registering would strand them; shut "
            "down the engine(s) using them (or pass force=True)")
    for n in cached:
        _INSTANCES.pop(n, None)
    _SCHEMES[scheme] = factory


def get_transport(name: str) -> Transport:
    """One shared instance per name: a stateful backend (socket listener,
    connection pool, emulated-link clock) keeps its state across every
    channel it backs; spec validation gets the same instance with no
    side effects."""
    inst = _INSTANCES.get(name)
    if inst is not None:
        return inst
    factory = _TRANSPORTS.get(name)
    if factory is None and ":" in name:
        scheme, _, args = name.partition(":")
        maker = _SCHEMES.get(scheme)
        if maker is not None:
            def factory(maker=maker, args=args):
                return maker(args)
    if factory is None:
        raise ValueError(
            f"unknown transport {name!r}; registered: "
            f"{sorted(_TRANSPORTS)} plus schemes "
            f"{sorted(s + ':' for s in _SCHEMES)}")
    inst = _INSTANCES[name] = factory()
    return inst
