"""Pluggable transports: the link between DEFER runtime entities.

Every hop in the serving topology — pump -> stage router, router -> replica
inbox, replica egress -> next stage, last stage -> collector — is a
:class:`Channel` obtained from a :class:`Transport`.  The wire *format*
(:class:`~repro.runtime.wire.BatchEnvelope` framing) is transport-agnostic;
a transport only moves already-encoded items between endpoints, so a socket
or emulated-link backend can slot in per stage without touching the codec
or batching layers.  Stage specs select a transport by name
(:class:`~repro.runtime.topology.StageSpec.transport`); new backends
register with :func:`register_transport`.

The in-process default is a bounded thread-safe queue — exactly the
structure the chain used before transports existed, so the staged-relay
backpressure semantics (a full channel blocks the sender) are unchanged.
``recv_nowait``/``recv(timeout=)`` raise :class:`queue.Empty`, mirroring
the stdlib so the node stage loops keep their idioms.
"""
from __future__ import annotations

import queue
from typing import Any, Callable

Empty = queue.Empty


class Channel:
    """One directed edge between runtime entities.

    ``send`` blocks when the channel is at capacity (backpressure is the
    runtime's flow control); ``recv`` blocks until an item arrives.  Items
    are opaque to the channel: envelopes, fence markers, and the stop
    token all ride the same FIFO, which is what makes the epoch fence
    ordering argument work on any transport that preserves per-channel
    FIFO delivery.
    """

    def send(self, item: Any) -> None:
        raise NotImplementedError

    def recv(self, timeout: float | None = None) -> Any:
        raise NotImplementedError

    def recv_nowait(self) -> Any:
        raise NotImplementedError

    def qsize(self) -> int:
        """Queued-item count, used as the least-queue-depth routing signal.
        Backends without cheap introspection keep this default: 0 for
        every channel makes lqd degrade gracefully to round-robin."""
        return 0


class InprocChannel(Channel):
    """The default transport's channel: a bounded in-process queue."""

    def __init__(self, capacity: int = 0):
        self._q: queue.Queue = queue.Queue(maxsize=capacity)

    def send(self, item: Any) -> None:
        self._q.put(item)

    def recv(self, timeout: float | None = None) -> Any:
        return self._q.get(timeout=timeout)

    def recv_nowait(self) -> Any:
        return self._q.get_nowait()

    def qsize(self) -> int:
        return self._q.qsize()


class Transport:
    """A channel factory.  Subclasses back channels with a different
    medium (sockets, an emulated lossy/slow link, shared memory)."""

    name = "abstract"

    def channel(self, capacity: int = 0) -> Channel:
        raise NotImplementedError


class InprocTransport(Transport):
    name = "inproc"

    def channel(self, capacity: int = 0) -> Channel:
        return InprocChannel(capacity)


_TRANSPORTS: dict[str, Callable[[], Transport]] = {
    "inproc": InprocTransport,
}
_INSTANCES: dict[str, Transport] = {}


def register_transport(name: str, factory: Callable[[], Transport]) -> None:
    """Make ``name`` usable as a :class:`StageSpec.transport` binding."""
    _TRANSPORTS[name] = factory
    _INSTANCES.pop(name, None)          # a re-registration replaces state


def get_transport(name: str) -> Transport:
    """One shared instance per name: a stateful backend (socket listener,
    connection pool, emulated-link clock) keeps its state across every
    channel it backs; spec validation gets the same instance with no
    side effects."""
    try:
        inst = _INSTANCES.get(name)
        if inst is None:
            inst = _INSTANCES[name] = _TRANSPORTS[name]()
        return inst
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r}; registered: "
            f"{sorted(_TRANSPORTS)}") from None
