"""Decode sessions: per-replica KV residency + the token-generation loop.

A decode *session* is one autoregressive generation: a prompt is prefilled
ONCE through the chain (``kind=K_OPEN``, full ``[1, S]`` token frame), every
attention layer's KV cache stays RESIDENT on the replica that computed it,
and each subsequent step ships only the newest token (``kind=K_STEP``,
``[1, 1]`` — plus its sequence position in the extent header), not the
growing sequence.  The per-hop payload is therefore O(d_model), independent
of how long the sequence has grown — the whole point of distributing decode.

Residency makes replicas stateful, which this module pays for in three
places:

* :class:`SessionStore` — the per-replica cache map (LRU-bounded so a
  leaked session cannot pin memory forever; an evicted session is NOT an
  error, its next step fails with ``SessionLost`` and the generate loop
  re-prefills).  Every live store registers in a module-level WeakSet so
  the test harness can assert session-keyed state is actually evicted on
  session end (the per-client-GC precedent from the admission merge).
* sticky routing — the stage routers pin a session to the replica holding
  its cache (:mod:`repro.runtime.router`); this module only *names* the
  session in each submit.
* :func:`generate_tokens` — the client-side loop.  It retains the full
  token history (prompt + generated), so ANY loss of residency — replica
  death, drain at a fence, repartition, LRU eviction — is recovered by
  re-opening the session (one re-prefill of the history) on whatever
  replicas the routers pick next.  Greedy decode is deterministic, so a
  recovered session's remaining tokens are bit-identical to an undisturbed
  run: a prefill of history ending at token ``t`` yields exactly the logits
  the failed step owed.

Recovery is ALWAYS re-prefill, never wire-level replay: the dispatcher's
blind replay layer is bypassed for session-tagged submits (a replayed step
against a cache that died with its replica would silently corrupt the
sequence).
"""
from __future__ import annotations

import threading
import uuid
import weakref
from collections import OrderedDict
from typing import Any, Iterator, Sequence

import numpy as np

from repro.runtime.wire import K_CLOSE, K_OPEN, K_STEP

# every constructed SessionStore, weakly: the conftest guard walks this to
# assert no session-keyed state survives a test (eviction on session end)
_LIVE_STORES: "weakref.WeakSet[SessionStore]" = weakref.WeakSet()


def live_session_stores() -> list["SessionStore"]:
    """Snapshot of every SessionStore still alive in this process."""
    return list(_LIVE_STORES)


class SessionLost(RuntimeError):
    """A session's KV residency is gone and recovery was not permitted
    (``restart='never'``, or the restart budget ran out).  Not retryable
    at the request layer — the caller must re-open the session (re-prefill
    its prompt) to continue."""

    retryable = False


class SessionStore:
    """Per-replica resident KV caches, keyed by session id.

    LRU-bounded: inserting past ``capacity`` evicts the least-recently
    *stepped* session.  Eviction is safe by protocol — the evicted
    session's next step gets a ``SessionLost`` error envelope and its
    generate loop re-prefills — so capacity is a memory ceiling, not a
    correctness knob.  All methods are thread-safe (the compute stage
    writes; fences and thread exits clear)."""

    def __init__(self, capacity: int = 64):
        self.capacity = max(1, int(capacity))
        self._lock = threading.Lock()
        self._caches: OrderedDict[Any, Any] = OrderedDict()
        _LIVE_STORES.add(self)

    def put(self, session: Any, cache: Any) -> None:
        with self._lock:
            self._caches.pop(session, None)
            self._caches[session] = cache
            while len(self._caches) > self.capacity:
                self._caches.popitem(last=False)

    def get(self, session: Any) -> Any | None:
        """Fetch a session's caches (refreshing its LRU slot), or None."""
        with self._lock:
            cache = self._caches.get(session)
            if cache is not None:
                self._caches.move_to_end(session)
            return cache

    def pop(self, session: Any) -> Any | None:
        with self._lock:
            return self._caches.pop(session, None)

    def clear(self) -> None:
        with self._lock:
            self._caches.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._caches)

    def keys(self) -> list[Any]:
        with self._lock:
            return list(self._caches)


def generate_tokens(dispatcher, prompt: Sequence[int],
                    max_new_tokens: int, *,
                    session_id: str | None = None,
                    client_id: Any = None,
                    restart: str = "auto",
                    deadline_s: float | None = None,
                    step_timeout: float | None = 60.0,
                    max_restarts: int = 4) -> Iterator[int]:
    """Greedy-decode ``max_new_tokens`` tokens through the chain, yielding
    each as it exits the tail.

    ``restart`` governs recovery when residency is lost mid-generation
    (replica killed, drained at a fence, repartitioned, LRU-evicted):

    * ``'always'`` — re-prefill from the retained history and continue;
    * ``'never'``  — raise :class:`SessionLost` (``retryable=False``);
    * ``'auto'``   — restart iff the dispatcher has a
      :class:`~repro.runtime.dispatcher.RetryPolicy` (the operator already
      opted into transparent recovery).

    ``max_restarts`` bounds CONSECUTIVE re-prefills without a completed
    step, so a persistently broken chain fails instead of looping.
    ``step_timeout`` bounds each future wait (a hung chain surfaces as a
    timeout, not a silent stall).  ``deadline_s`` applies per submitted
    frame (open and step alike), riding the dispatcher's deadline reaper.

    The generator's ``finally`` closes the session: it unregisters from
    the dispatcher and sends a best-effort ``K_CLOSE`` frame down the
    chain so every stage evicts its caches promptly (LRU would get them
    eventually; close keeps the stores tight — and lets the test
    harness assert eviction on session end).
    """
    from repro.runtime.dispatcher import NodeError  # circular at import time

    graph = dispatcher.graph
    if not getattr(graph, "decode_capable", False):
        raise ValueError(
            f"graph {graph.name!r} is not decode-capable: it declares no "
            "LayerDecode nodes, or is not a pure chain")
    history = [int(t) for t in np.asarray(prompt, np.int64).reshape(-1)]
    if not history:
        raise ValueError("decode needs a non-empty prompt")
    if max_new_tokens < 1:
        raise ValueError("max_new_tokens must be >= 1")
    cache_len = getattr(graph, "decode_cache_len", None)
    if cache_len is not None and len(history) + max_new_tokens > cache_len:
        raise ValueError(
            f"prompt ({len(history)}) + max_new_tokens ({max_new_tokens}) "
            f"exceeds the graph's KV capacity ({cache_len})")
    if restart not in ("auto", "always", "never"):
        raise ValueError(f"restart={restart!r}: use auto | always | never")
    allow_restart = (restart == "always"
                     or (restart == "auto"
                         and dispatcher.retry_policy is not None))

    sid = session_id if session_id is not None \
        else f"sess-{uuid.uuid4().hex[:16]}"
    cid = client_id if client_id is not None else sid

    def _open() -> np.ndarray:
        """(Re-)prefill the full retained history; the tail trims to the
        last position, so the result is the next-token logits — exactly
        what the step this replaces would have produced."""
        x = np.asarray(history, np.int32).reshape(1, -1)
        fut = dispatcher.submit(x, client_id=cid, session=sid,
                                session_pos=0, session_kind=K_OPEN,
                                deadline_s=deadline_s)
        return np.asarray(fut.result(step_timeout))

    def _step(tok: int) -> np.ndarray:
        x = np.asarray([[tok]], np.int32)
        fut = dispatcher.submit(x, client_id=cid, session=sid,
                                session_pos=len(history) - 1,
                                session_kind=K_STEP,
                                deadline_s=deadline_s)
        return np.asarray(fut.result(step_timeout))

    def _advance(tok: int | None) -> np.ndarray:
        """One chain round-trip with recovery: a displaced or failed
        session re-opens (full-history prefill) up to ``max_restarts``
        times before giving up."""
        restarts = 0
        reopen = tok is None or dispatcher.session_displaced(sid)
        while True:
            try:
                return _open() if reopen else _step(tok)
            except NodeError as e:
                if not allow_restart or restarts >= max_restarts:
                    raise SessionLost(
                        f"session {sid!r} lost its KV residency and "
                        f"restart={restart!r} forbids recovery (or the "
                        f"{max_restarts}-restart budget ran out); re-open "
                        "the session to continue") from e
                restarts += 1
                dispatcher.session_displaced(sid)   # clear any stale flag
                reopen = True

    dispatcher.session_register(sid)
    try:
        logits = _advance(None)
        made = 0
        while True:
            tok = int(np.argmax(logits[0, -1]))
            yield tok
            history.append(tok)
            made += 1
            if made >= max_new_tokens:
                return
            logits = _advance(tok)
    finally:
        dispatcher.session_unregister(sid)
        try:
            fut = dispatcher.submit(
                np.zeros((1, 1), np.int32), client_id=cid, session=sid,
                session_pos=0, session_kind=K_CLOSE, block=False)
            fut.result(timeout=5.0)
        except Exception:  # deferlint: swallow(best-effort close; LRU eviction and the store-clearing fence/exit paths reclaim the caches anyway)
            pass
