"""Declarative serving topology: stages x replicas x transports.

DEFER's original runtime hard-wired one shape — a linear chain with exactly
one compute node per partition.  The follow-on work (SEIFER, arXiv
2210.12218/12219) gets its throughput from *replicating* bottleneck
partitions across a cluster, so the serving API is now topology-first: a
:class:`TopologySpec` lists the stages, and each :class:`StageSpec` binds a
contiguous layer range to a replica count, a routing policy, a transport,
and optional per-stage batching-knob overrides.  The dispatcher builds
whatever the spec says; nothing about "a chain of N nodes" is implicit
anymore.

    spec = TopologySpec.chain(graph, 4, strategy="balanced_latency")
    spec = spec.with_replicas(2, 3)          # stage 2 gets 3 replicas
    engine = InferenceEngine(graph, spec, codecs)

``TopologySpec.chain`` delegates cut selection to the partitioner (any
strategy, or explicit ``cuts``); hand-built specs pass explicit layer
ranges.  Replica counts are a *starting* point — ``Engine.scale(stage, n)``
grows or drains a live stage behind the epoch fence.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

from repro.runtime.transport import get_transport

if TYPE_CHECKING:
    from repro.core.graph import LayerGraph
    from repro.core.partitioner import LinkModel


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One pipeline stage: a layer range served by ``replicas`` identical
    compute nodes behind a router.

    ``routing`` spreads work across the replicas: ``"lqd"``
    (least-queue-depth, the default — adapts to replica jitter) or
    ``"rr"`` (strict round-robin).  ``transport`` names a registered
    :class:`~repro.runtime.transport.Transport` backing this stage's
    channels — ``"inproc"`` (default), ``"tcp"`` (real loopback sockets),
    an emulated link like ``"link:10mbit,20ms"`` (the paper's CORE
    conditions), or any backend registered with ``register_transport``;
    stages may each bind a different one.  ``max_batch`` / ``coalesce_s``
    / ``shape_buckets`` / ``max_batch_cap`` override the engine-wide
    defaults for this stage only (None = inherit).

    ``session_capacity`` bounds each replica's resident decode-session KV
    caches (LRU eviction past it — an evicted session re-prefills, so this
    is a memory ceiling, not a correctness knob; None = runtime default).
    """

    layers: tuple[int, int]                 # [lo, hi) over graph.nodes
    replicas: int = 1
    transport: str = "inproc"
    routing: str = "lqd"
    max_batch: int | None = None
    coalesce_s: float | None = None
    shape_buckets: str | None = None
    max_batch_cap: int | None = None
    session_capacity: int | None = None


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """The whole serving topology: an ordered tuple of stages whose layer
    ranges tile the graph."""

    stages: tuple[StageSpec, ...]

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def bounds(self) -> list[int]:
        return [self.stages[0].layers[0]] + [s.layers[1] for s in self.stages]

    @property
    def cuts(self) -> tuple[int, ...]:
        return tuple(s.layers[1] for s in self.stages[:-1])

    @property
    def replicas(self) -> tuple[int, ...]:
        return tuple(s.replicas for s in self.stages)

    def validate(self, graph: "LayerGraph") -> None:
        if not self.stages:
            raise ValueError("a topology needs at least one stage")
        n = len(graph.nodes)
        if self.stages[0].layers[0] != 0 or self.stages[-1].layers[1] != n:
            raise ValueError(
                f"stages must cover layers [0, {n}); got "
                f"{[s.layers for s in self.stages]}")
        for a, b in zip(self.stages, self.stages[1:]):
            if a.layers[1] != b.layers[0]:
                raise ValueError(
                    f"stage ranges must be contiguous: {a.layers} then "
                    f"{b.layers}")
        for s in self.stages:
            lo, hi = s.layers
            if hi <= lo:
                raise ValueError(f"empty stage range {s.layers}")
            if s.replicas < 1:
                raise ValueError(f"stage {s.layers}: replicas must be >= 1")
            if s.routing not in ("rr", "lqd"):
                raise ValueError(f"unknown routing policy {s.routing!r}")
            get_transport(s.transport)      # raises on unknown binding

    def with_replicas(self, stage: int, replicas: int) -> "TopologySpec":
        """A copy with one stage's replica count changed."""
        stages = list(self.stages)
        stages[stage] = dataclasses.replace(stages[stage], replicas=replicas)
        return TopologySpec(tuple(stages))

    def with_layers(self, bounds: Sequence[int]) -> "TopologySpec":
        """A copy with every stage's layer range replaced (same stage
        count) — how a live repartition updates the spec."""
        if len(bounds) != len(self.stages) + 1:
            raise ValueError(f"{len(bounds)} bounds for "
                             f"{len(self.stages)} stages")
        stages = [dataclasses.replace(s, layers=(lo, hi))
                  for s, lo, hi in zip(self.stages, bounds, bounds[1:])]
        return TopologySpec(tuple(stages))

    @classmethod
    def chain(cls, graph: "LayerGraph", num_stages: int,
              strategy: str = "equal_layers",
              link: "LinkModel | None" = None,
              cuts: Sequence[int] | None = None,
              replicas: "int | Sequence[int] | None" = None,
              **stage_kw) -> "TopologySpec":
        """The classic DEFER shape: ``num_stages`` stages in series, layer
        ranges chosen by the partitioner (or pinned with ``cuts``).
        ``replicas`` seeds every stage (int) or each stage (sequence);
        extra keyword args apply to every stage (e.g. ``routing="rr"``)."""
        from repro.core.partitioner import partition
        plan = partition(graph, num_stages, strategy=strategy, link=link,
                         cuts=cuts)
        if replicas is None:
            reps = [1] * num_stages
        elif isinstance(replicas, int):
            reps = [replicas] * num_stages
        else:
            reps = list(replicas)
            if len(reps) != num_stages:
                raise ValueError(f"{len(reps)} replica counts for "
                                 f"{num_stages} stages")
        return cls(tuple(StageSpec(layers=(lo, hi), replicas=r, **stage_kw)
                         for (lo, hi), r in zip(plan.ranges(), reps)))
