"""A DEFER compute node (paper Algorithm 2), in-process.

Each node owns: an incoming FIFO queue (its listening socket), a reference
to the next node's queue (its outgoing socket), and — after the
configuration step — a materialized model partition.  A worker thread loops
read -> deserialize -> infer -> serialize -> relay, exactly the paper's
THREAD-1/THREAD-2 pair collapsed into the FIFO discipline they implement.

Timings are recorded per sample so the engine can report the same metrics
the paper measures (compute, overhead, payload) from *real* execution.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.core.graph import LayerGraph, LayerNode
from repro.runtime.wire import WireCodec, WireRecord, tree_unflatten_paths

_STOP = object()


@dataclasses.dataclass
class SampleTrace:
    node: int
    deserialize_s: float
    compute_s: float
    serialize_s: float
    payload_bytes: int


class ComputeNode:
    """One compute node in the chain."""

    def __init__(self, index: int, data_codec: WireCodec, queue_depth: int = 8):
        self.index = index
        self.data_codec = data_codec
        self.inbox: queue.Queue = queue.Queue(maxsize=queue_depth)
        self.next_inbox: queue.Queue | None = None
        self.traces: list[SampleTrace] = []
        self.config_records: list[WireRecord] = []
        self._graph: LayerGraph | None = None
        self._nodes: list[LayerNode] = []
        self._params: dict | None = None
        self._required: list[str] = []
        self._exported: list[str] = []
        self._apply = None
        self._thread: threading.Thread | None = None

    # -- configuration step (paper §III-B) ----------------------------------
    def configure(self, graph: LayerGraph, lo: int, hi: int,
                  arch_blob: bytes, weights_blob: bytes,
                  weights_codec: WireCodec) -> None:
        """Receive architecture + weights over the wire and build the model.

        ``graph`` supplies only the layer *functions* (code is pre-installed
        on nodes, as in the paper — TF/Keras is on every device); topology
        and weights come from the wire blobs.
        """
        t0 = time.perf_counter()
        import json
        spec = json.loads(arch_blob.decode())
        flat, dec_s = weights_codec.decode_tree(weights_blob)
        nested = tree_unflatten_paths(flat)
        t1 = time.perf_counter()
        self.config_records.append(
            WireRecord("architecture", len(arch_blob), len(arch_blob), 0.0, 0.0))
        self.config_records.append(
            WireRecord("weights", sum(a.nbytes for a in flat.values()),
                       len(weights_blob), 0.0, t1 - t0))
        self._graph = graph
        self._nodes = graph.slice_nodes(lo, hi)
        assert [n.name for n in self._nodes] == spec["layers"], \
            "wire architecture disagrees with local layer code"
        # chain semantics: inbound wire = everything crossing the cut before
        # this stage; outbound = everything crossing the cut after (includes
        # pass-through activations this stage merely relays)
        self._required = graph.crossing_names(lo - 1) if lo > 0 else [""]
        self._exported = (graph.crossing_names(hi - 1) if hi < len(graph.nodes)
                          else [graph.nodes[-1].name])
        self._params = {k: jax.tree_util.tree_map(jax.numpy.asarray, v)
                        for k, v in nested.items()}
        self._make_apply()

    def _make_apply(self):
        nodes, params = self._nodes, self._params
        required, exported = self._required, self._exported

        def apply_fn(boundary: dict[str, Any]) -> dict[str, Any]:
            acts = dict(boundary)
            for node in nodes:
                args = [acts[i] for i in node.inputs]
                acts[node.name] = node.fn(params.get(node.name, {}), *args)
            return {n: acts[n] for n in exported}

        self._apply = jax.jit(apply_fn)

    # -- inference step (paper §III-C) ----------------------------------------
    def start(self) -> None:
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self.inbox.put(_STOP)
        if self._thread:
            self._thread.join()

    def _loop(self) -> None:
        while True:
            item = self.inbox.get()
            if item is _STOP:
                if self.next_inbox is not None:
                    self.next_inbox.put(_STOP)
                return
            seq, blob = item
            out_blob, trace = self.process(blob)
            self.traces.append(trace)
            if self.next_inbox is not None:
                self.next_inbox.put((seq, out_blob))

    def process(self, blob: bytes) -> tuple[bytes, SampleTrace]:
        flat, des_s = self.data_codec.decode_tree(blob)
        boundary = {k: jax.numpy.asarray(v) for k, v in flat.items()}
        t0 = time.perf_counter()
        outs = self._apply(boundary)
        outs = {k: np.asarray(v) for k, v in outs.items()}  # block
        t1 = time.perf_counter()
        out_blob, rec = self.data_codec.encode_tree(outs, "data")
        return out_blob, SampleTrace(self.index, des_s, t1 - t0,
                                     rec.encode_s, rec.wire_bytes)
