"""A DEFER compute node (paper Algorithm 2), in-process, with
continuous batching.

Each node owns: an incoming FIFO queue (its listening socket), a reference
to the next node's queue (its outgoing socket), and — after the
configuration step — a materialized model partition.  The worker thread
loops read -> deserialize -> infer -> serialize -> relay, exactly the
paper's THREAD-1/THREAD-2 pair collapsed into the FIFO discipline they
implement, with one serving extension: up to ``max_batch`` queued
envelopes are drained per step, their activations bucketed by shape and
padded to a power-of-two batch, computed in ONE partition apply, and split
back into per-request envelopes before the relay.  Requests of different
shapes land in different buckets and may legally reorder; the dispatcher
demuxes results per client, not globally.

Timings are recorded per batch so the engine can report the same metrics
the paper measures (compute, overhead, payload) plus the serving ones
(utilization, queue depth, batch occupancy) from *real* execution.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.core.graph import LayerGraph, LayerNode
from repro.runtime.wire import (Envelope, WireCodec, WireRecord,
                                tree_unflatten_paths)

_STOP = object()


@dataclasses.dataclass
class BatchTrace:
    """Timings for one drained batch (n requests computed together)."""

    node: int
    n: int                       # requests in the batch
    padded: int                  # rows actually computed (after padding)
    deserialize_s: float         # summed over the batch's requests
    compute_s: float             # one apply over the stacked batch
    serialize_s: float           # summed over the batch's requests
    payload_bytes: int           # summed outbound wire bytes


def _bucket_rows(n: int) -> int:
    """Next power of two >= n: bounds jit specializations per signature."""
    p = 1
    while p < n:
        p *= 2
    return p


class ComputeNode:
    """One compute node in the chain."""

    def __init__(self, index: int, data_codec: WireCodec,
                 queue_depth: int = 8, max_batch: int = 8,
                 pad_batches: bool = True):
        self.index = index
        self.data_codec = data_codec
        self.max_batch = max(1, max_batch)
        self.pad_batches = pad_batches
        self.inbox: queue.Queue = queue.Queue(maxsize=queue_depth)
        self.next_inbox: queue.Queue | None = None
        self.traces: list[BatchTrace] = []
        self.queue_depths: list[int] = []
        self.busy_s: float = 0.0
        self.config_records: list[WireRecord] = []
        self._graph: LayerGraph | None = None
        self._nodes: list[LayerNode] = []
        self._params: dict | None = None
        self._required: list[str] = []
        self._exported: list[str] = []
        self._apply = None
        self._thread: threading.Thread | None = None
        self._stats_lock = threading.Lock()

    # -- configuration step (paper §III-B) ----------------------------------
    def configure(self, graph: LayerGraph, lo: int, hi: int,
                  arch_blob: bytes, weights_blob: bytes,
                  weights_codec: WireCodec) -> None:
        """Receive architecture + weights over the wire and build the model.

        ``graph`` supplies only the layer *functions* (code is pre-installed
        on nodes, as in the paper — TF/Keras is on every device); topology
        and weights come from the wire blobs.
        """
        t0 = time.perf_counter()
        import json
        spec = json.loads(arch_blob.decode())
        flat, dec_s = weights_codec.decode_tree(weights_blob)
        nested = tree_unflatten_paths(flat)
        t1 = time.perf_counter()
        self.config_records.append(
            WireRecord("architecture", len(arch_blob), len(arch_blob), 0.0, 0.0))
        self.config_records.append(
            WireRecord("weights", sum(a.nbytes for a in flat.values()),
                       len(weights_blob), 0.0, t1 - t0))
        self._graph = graph
        self._nodes = graph.slice_nodes(lo, hi)
        assert [n.name for n in self._nodes] == spec["layers"], \
            "wire architecture disagrees with local layer code"
        # chain semantics: inbound wire = everything crossing the cut before
        # this stage; outbound = everything crossing the cut after (includes
        # pass-through activations this stage merely relays)
        self._required = graph.crossing_names(lo - 1) if lo > 0 else [""]
        self._exported = (graph.crossing_names(hi - 1) if hi < len(graph.nodes)
                          else [graph.nodes[-1].name])
        self._params = {k: jax.tree_util.tree_map(jax.numpy.asarray, v)
                        for k, v in nested.items()}
        self._make_apply()

    def _make_apply(self):
        nodes, params = self._nodes, self._params
        exported = self._exported

        def apply_fn(boundary: dict[str, Any]) -> dict[str, Any]:
            acts = dict(boundary)
            for node in nodes:
                args = [acts[i] for i in node.inputs]
                acts[node.name] = node.fn(params.get(node.name, {}), *args)
            return {n: acts[n] for n in exported}

        self._apply = jax.jit(apply_fn)

    # -- inference step (paper §III-C) ----------------------------------------
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self.inbox.put(_STOP)
        if self._thread:
            self._thread.join()

    def reset_stats(self) -> None:
        with self._stats_lock:
            self.traces = []
            self.queue_depths = []
            self.busy_s = 0.0

    def _loop(self) -> None:
        while True:
            item = self.inbox.get()
            if item is _STOP:
                if self.next_inbox is not None:
                    self.next_inbox.put(_STOP)
                return
            # continuous batching: drain whatever is already queued, up to
            # max_batch, without waiting for more arrivals
            batch = [item]
            saw_stop = False
            while len(batch) < self.max_batch:
                try:
                    nxt = self.inbox.get_nowait()
                except queue.Empty:
                    break
                if nxt is _STOP:
                    saw_stop = True
                    break
                batch.append(nxt)
            with self._stats_lock:
                self.queue_depths.append(len(batch) + self.inbox.qsize())
            t0 = time.perf_counter()
            outs = self.process_batch(batch)
            with self._stats_lock:
                self.busy_s += time.perf_counter() - t0
            if self.next_inbox is not None:
                for env in outs:
                    self.next_inbox.put(env)
            if saw_stop:
                if self.next_inbox is not None:
                    self.next_inbox.put(_STOP)
                return

    # -- batched partition apply ---------------------------------------------
    def process_batch(self, envs: list[Envelope]) -> list[Envelope]:
        """Decode, bucket-by-shape, pad, compute once, split, re-encode."""
        des_total = 0.0
        samples: list[tuple[Envelope, dict[str, np.ndarray]]] = []
        for env in envs:
            flat, des_s = self.data_codec.decode_tree(env.blob)
            des_total += des_s
            samples.append((env, {k: np.asarray(v) for k, v in flat.items()}))

        # bucket by activation signature: only identically-shaped requests
        # can share a stacked apply
        buckets: dict[tuple, list[tuple[Envelope, dict]]] = {}
        for env, boundary in samples:
            sig = tuple(sorted((k, v.shape, str(v.dtype))
                               for k, v in boundary.items()))
            buckets.setdefault(sig, []).append((env, boundary))

        out_envs: list[Envelope] = []
        compute_total = 0.0
        ser_total = 0.0
        payload_total = 0
        padded_rows = 0
        for group in buckets.values():
            rows = [next(iter(b.values())).shape[0] for _, b in group]
            total = sum(rows)
            target = _bucket_rows(total) if self.pad_batches else total
            padded_rows += target

            stacked: dict[str, jax.Array] = {}
            for key in group[0][1]:
                arrs = [b[key] for _, b in group]
                cat = np.concatenate(arrs, axis=0) if len(arrs) > 1 else arrs[0]
                if target > total:
                    pad = np.zeros((target - total,) + cat.shape[1:],
                                   cat.dtype)
                    cat = np.concatenate([cat, pad], axis=0)
                stacked[key] = jax.numpy.asarray(cat)

            t0 = time.perf_counter()
            outs = self._apply(stacked)
            outs = {k: np.asarray(v) for k, v in outs.items()}  # block
            compute_total += time.perf_counter() - t0

            off = 0
            for (env, _), b_rows in zip(group, rows):
                piece = {k: v[off:off + b_rows] for k, v in outs.items()}
                off += b_rows
                blob, rec = self.data_codec.encode_tree(
                    piece, "data", request_id=env.request_id,
                    client_id=env.client_id)
                ser_total += rec.encode_s
                payload_total += rec.wire_bytes
                out_envs.append(dataclasses.replace(env, blob=blob))

        with self._stats_lock:
            self.traces.append(BatchTrace(
                self.index, len(envs), padded_rows, des_total, compute_total,
                ser_total, payload_total))
        return out_envs
