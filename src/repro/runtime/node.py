"""A DEFER compute node (paper Algorithm 2) as a 3-stage internal pipeline.

Each node is one REPLICA of one topology stage: it owns an incoming FIFO
channel (its listening socket — a :class:`~repro.runtime.transport.Channel`
from the stage's transport binding), a reference to the next stage's input
channel (its outgoing socket), and — after the configuration step — a
materialized model partition.  Replicas of the same stage are identical;
the stage's router spreads work across them and this node neither knows
nor cares whether it has siblings.  The paper's
THREAD-1/THREAD-2 pair is generalized into three stages connected by
depth-2 bounded queues (double buffering), so codec work overlaps compute:

    inbox -> [ingress: decode]
          -> _to_compute -> [compute: merge/bucket/stack/apply]
          -> _to_encode  -> [egress: encode ONCE per bucket, relay]
          -> next node's inbox

While batch N runs the jitted partition apply, batch N+1 is deserializing
on the ingress thread and batch N-1 is serializing on the egress thread.
Continuous batching happens at the compute stage: up to ``max_batch``
requests' worth of decoded payloads are merged per step, bucketed by
activation signature (trailing dims + dtype — row counts may be ragged),
concatenated, padded to a power-of-two row count, and computed in ONE
partition apply.  The egress stage then encodes each bucket's stacked
output ONCE — batch-level wire encoding with row-extent framing in the
:class:`BatchEnvelope` — instead of one codec pass per request, so fixed
codec cost amortizes across the batch and the next hop decodes once.

Failure isolation: an exception in any stage's decode/apply/encode is
caught per batch; the affected requests' extents travel on as an ``error``
envelope (formatted traceback) that downstream stages relay untouched, the
collector fails exactly those futures, and the node keeps serving
subsequent batches.

Timings are recorded per batch (``BatchTrace``) and per stage
(``busy_decode_s`` / ``busy_compute_s`` / ``busy_encode_s``), so the engine
can report the paper's metrics (compute, overhead, payload) plus the
serving ones (per-stage utilization, queue depth, batch occupancy) from
*real* execution — and so the codec/compute overlap is directly measurable.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import traceback
from typing import Any

import jax
import numpy as np

from repro.core.graph import LayerGraph, LayerNode
from repro.runtime.session import SessionStore
from repro.runtime.transport import Channel, ChannelClosed, InprocChannel
# _STOP / _RETIRE live in wire.py so the byte framing can map them to
# dedicated frame types (a socket transport must carry them too); they are
# re-exported here because the runtime modules treat this as their home.
# _RETIRE drains ONE replica out of a stage without touching the rest of
# the chain: it flows through the replica's internal stages like _STOP —
# so everything already in its queues completes and relays — but the
# egress exits WITHOUT forwarding it downstream, so the next stage's
# _STOP accounting never sees a retired replica.
from repro.runtime.wire import (_RETIRE, _STOP, K_CLOSE,  # noqa: F401
                                K_OPEN, K_PLAIN, K_STEP, BatchEnvelope,
                                ReconfigMarker, RowExtent, WireCodec,
                                WireRecord, slice_parts,
                                tree_unflatten_paths)


@dataclasses.dataclass
class BatchTrace:
    """Timings for one merged batch (n requests computed together)."""

    node: int
    n: int                       # requests in the batch
    padded: int                  # rows actually computed (after padding)
    deserialize_s: float         # summed over the batch's inbound envelopes
    compute_s: float             # apply over the stacked buckets
    serialize_s: float           # summed over the batch's outbound encodes
    payload_bytes: int           # summed outbound wire bytes
    encodes: int = 0             # outbound codec passes (== buckets, not n)


@dataclasses.dataclass
class _Decoded:
    """Ingress -> compute: one inbound envelope, decoded once."""

    extents: list[RowExtent]
    boundary: dict[str, np.ndarray]      # stacked over the envelope's extents
    deserialize_s: float


@dataclasses.dataclass
class _Computed:
    """Compute -> egress: one merged batch's bucket outputs + its trace."""

    buckets: list[tuple[list[RowExtent], dict[str, np.ndarray]]]
    trace: BatchTrace


def _bucket_rows(n: int) -> int:
    """Next power of two >= n: bounds jit specializations per signature."""
    p = 1
    while p < n:
        p *= 2
    return p


def _signature(boundary: dict[str, np.ndarray]) -> tuple:
    """Bucket key: leaf names + trailing dims + dtypes.  Row counts are
    free to differ — ragged requests concatenate along axis 0."""
    return tuple(sorted((k, v.shape[1:], str(v.dtype))
                        for k, v in boundary.items()))


def _pad_middle(arr: np.ndarray) -> np.ndarray:
    """Zero-pad every middle axis up to the next power of two (no-op for
    rank <= 2 or already-pow2 sizes)."""
    if arr.ndim <= 2:
        return arr
    pads = [(0, 0)] + [(0, _bucket_rows(s) - s) for s in arr.shape[1:-1]] \
        + [(0, 0)]
    if all(p == (0, 0) for p in pads):
        return arr
    return np.pad(arr, pads)


class ComputeNode:
    """One compute node in the chain."""

    def __init__(self, index: int, data_codec: WireCodec,
                 queue_depth: int = 8, max_batch: int = 8,
                 pad_batches: bool = True, staged: bool = True,
                 stage_depth: int = 2, coalesce_s: float = 0.005,
                 shape_buckets: str = "exact",
                 max_batch_cap: int | None = None,
                 replica: int = 0,
                 inbox: Channel | None = None,
                 session_capacity: int = 64):
        self.index = index              # stage index (ReconfigMarker plans
        self.replica = replica          # are keyed by it); replica id within
        self.data_codec = data_codec    # the stage
        # max_batch and coalesce_s are ADAPTIVE knobs: the serving
        # controller retunes them online from the measured codec/compute
        # stage-time ratio (plain attribute writes; each wave re-reads them)
        self.max_batch = max(1, max_batch)
        self.pad_batches = pad_batches
        self.staged = staged
        self.coalesce_s = coalesce_s
        # "pow2": near-miss trailing shapes merge into one apply via
        # bucketed pad-to-shape (opt-in: requires layers that preserve and
        # act independently along the padded middle axes)
        assert shape_buckets in ("exact", "pow2")
        self.shape_buckets = shape_buckets
        # ceiling for the controller's adaptive max_batch growth;
        # precompile() traces up to the cap so growth never compiles
        # inside a serving window
        self.max_batch_cap = max(self.max_batch, max_batch_cap or 0)
        self.epoch = 0              # last ReconfigMarker this node committed
        self.retiring = False       # drained by scale(), flushing until the
                                    # fence + retire token clear its queues
        self.inbox: Channel = inbox if inbox is not None \
            else InprocChannel(queue_depth)
        self.next_inbox: Channel | None = None
        self._egress_epoch = 0      # epoch stamp for outbound envelopes
        self._to_compute: queue.Queue = queue.Queue(maxsize=max(1, stage_depth))
        self._to_encode: queue.Queue = queue.Queue(maxsize=max(1, stage_depth))
        # an item popped for a wave/merge that would overflow max_batch is
        # stashed here and leads the next wave (queues can't push back)
        self._ingress_pending = None
        self._compute_pending = None
        self.traces: list[BatchTrace] = []
        self.queue_depths: list[int] = []
        # running totals over the window (kept alongside the trace list so
        # the controller's periodic snapshot() is O(1), not O(waves))
        self._depth_sum = 0
        self._depth_count = 0
        self._trace_n = 0
        self._trace_compute_s = 0.0
        self._trace_serialize_s = 0.0
        self._trace_deserialize_s = 0.0
        self._trace_payload_bytes = 0
        self._trace_encodes = 0
        self.busy_decode_s: float = 0.0
        self.busy_compute_s: float = 0.0
        self.busy_encode_s: float = 0.0
        self.config_records: list[WireRecord] = []
        self._graph: LayerGraph | None = None
        self._nodes: list[LayerNode] = []
        self._pad_safe = True
        self._params: dict | None = None
        self._required: list[str] = []
        self._exported: list[str] = []
        self._apply = None
        # decode-session state: resident KV caches for sessions pinned to
        # this replica (LRU-bounded — see SessionStore), plus the jitted
        # prefill/step applies built only when the graph is decode-capable
        self.sessions = SessionStore(session_capacity)
        self._prefill_apply = None
        self._decode_apply = None
        self._is_tail = False
        self._threads: list[threading.Thread] = []
        self._stats_lock = threading.Lock()
        # live gauge (NOT a window counter — reset_stats leaves it):
        # requests consumed off the inbox but not yet emitted downstream.
        # A wedged compute thread that swallowed its whole backlog shows
        # inbox qsize 0 (credits returned on consume), so stall detection
        # needs this to see work trapped inside the pipeline.
        self._inflight_n = 0

    @property
    def busy_s(self) -> float:
        """Total busy time summed over stages (can exceed wall time when
        stages overlap — report per-stage utilization, not this / wall)."""
        return self.busy_decode_s + self.busy_compute_s + self.busy_encode_s

    # -- configuration step (paper §III-B) ----------------------------------
    def configure(self, graph: LayerGraph, lo: int, hi: int,
                  arch_blob: bytes, weights_blob: bytes,
                  weights_codec: WireCodec) -> None:
        """Receive architecture + weights over the wire and build the model.

        ``graph`` supplies only the layer *functions* (code is pre-installed
        on nodes, as in the paper — TF/Keras is on every device); topology
        and weights come from the wire blobs.
        """
        t0 = time.perf_counter()
        import json
        spec = json.loads(arch_blob.decode())
        flat, dec_s = weights_codec.decode_tree(weights_blob)
        nested = tree_unflatten_paths(flat)
        t1 = time.perf_counter()
        self.config_records.append(
            WireRecord("architecture", len(arch_blob), len(arch_blob), 0.0, 0.0))
        self.config_records.append(
            WireRecord("weights", sum(a.nbytes for a in flat.values()),
                       len(weights_blob), 0.0, t1 - t0))
        self._graph = graph
        self._set_range(lo, hi)
        assert [n.name for n in self._nodes] == spec["layers"], \
            "wire architecture disagrees with local layer code"
        self._params = {k: jax.tree_util.tree_map(jax.numpy.asarray, v)
                        for k, v in nested.items()}
        self._make_apply()

    def _set_range(self, lo: int, hi: int) -> None:
        """Adopt layer range [lo, hi): chain semantics say inbound wire =
        everything crossing the cut before this stage; outbound = everything
        crossing the cut after (includes pass-through activations this
        stage merely relays)."""
        graph = self._graph
        self._nodes = graph.slice_nodes(lo, hi)
        self._required = graph.crossing_names(lo - 1) if lo > 0 else [""]
        self._exported = (graph.crossing_names(hi - 1) if hi < len(graph.nodes)
                          else [graph.nodes[-1].name])
        # the tail stage trims decode outputs to the last position, so a
        # prefill's full-sequence logits never ship past the final hop
        self._is_tail = hi == len(graph.nodes)
        # pow2 pad-to-shape assumes every layer in the slice preserves and
        # acts independently along padded middle axes; a single pad-unsafe
        # layer (attention over the padded axis) makes this segment fall
        # back to exact bucketing
        self._pad_safe = all(n.pad_safe for n in self._nodes)

    def _apply_reconfig(self, marker: ReconfigMarker) -> None:
        """Commit a live repartition at the epoch fence (compute stage).

        Runs on the compute thread exactly when the marker passes it, so
        every envelope ahead of the marker was computed with the old
        partition and every one behind it gets the new — no request sees a
        mixed chain.  Weights arrive as a DIFF: only layers this node
        gains were shipped; layers it keeps are reused in place, layers it
        loses are dropped."""
        plan = marker.plans.get(self.index)
        self.epoch = marker.epoch
        if plan is None:                 # this node's range did not change
            return
        import json
        t0 = time.perf_counter()
        spec = json.loads(plan.arch_blob.decode())
        params = {name: self._params[name] for name in spec["layers"]
                  if name in self._params}
        if plan.weights_blob:
            flat, _ = plan.weights_codec.decode_tree(plan.weights_blob)
            for name, v in tree_unflatten_paths(flat).items():
                params[name] = jax.tree_util.tree_map(jax.numpy.asarray, v)
        self._set_range(plan.lo, plan.hi)
        assert [n.name for n in self._nodes] == spec["layers"], \
            "wire architecture disagrees with local layer code"
        # param-less layers (pool / add / activation nodes) legitimately
        # have no wire entry — only parameterized layers must have arrived
        missing = [n.name for n in self._nodes if n.name not in params
                   and jax.tree_util.tree_leaves(n.param_spec)]
        assert not missing, f"reconfig weights diff is missing {missing}"
        self._params = params
        # the layer slice moved: every resident KV cache is keyed to the
        # OLD slice and is now meaningless — drop them all.  The dispatcher
        # displaces every active session at the same fence, so their
        # generate loops re-prefill instead of stepping into SessionLost.
        self.sessions.clear()
        self._make_apply()
        self.config_records.append(WireRecord(
            "reconfig", sum(np.asarray(l).nbytes for l in
                            jax.tree_util.tree_leaves(params)),
            plan.wire_bytes, 0.0, time.perf_counter() - t0))

    def _make_apply(self):
        nodes, params = self._nodes, self._params
        exported = self._exported

        def apply_fn(boundary: dict[str, Any]) -> dict[str, Any]:
            acts = dict(boundary)
            for node in nodes:
                args = [acts[i] for i in node.inputs]
                acts[node.name] = node.fn(params.get(node.name, {}), *args)
            return {n: acts[n] for n in exported}

        self._apply = jax.jit(apply_fn)

        # autoregressive view of the same slice: prefill walks the chain
        # once over a full prompt collecting each stateful layer's KV
        # cache; step consumes one token per row against stacked caches
        # (rows may sit at different sequence positions).  Only built for
        # decode-capable graphs — a pure chain, so the slice has exactly
        # one inbound and one outbound boundary activation.
        self._prefill_apply = None
        self._decode_apply = None
        graph = self._graph
        if (graph is None or not graph.decode_capable or not nodes
                or len(self._required) != 1 or len(exported) != 1):
            return

        def prefill_fn(x):
            acts = x
            caches = {}
            for node in nodes:
                p = params.get(node.name, {})
                if node.decode is not None:
                    acts, caches[node.name] = node.decode.prefill_fn(p, acts)
                else:
                    acts = node.fn(p, acts)
            return acts, caches

        def step_fn(caches, x, pos):
            acts = x
            new = {}
            for node in nodes:
                p = params.get(node.name, {})
                if node.decode is not None:
                    acts, new[node.name] = node.decode.step_fn(
                        p, caches[node.name], acts, pos)
                else:
                    acts = node.fn(p, acts)
            return acts, new

        self._prefill_apply = jax.jit(prefill_fn)
        self._decode_apply = jax.jit(step_fn)

    def precompile(self) -> None:
        """Trace/compile every power-of-two padded batch specialization this
        node can hit under continuous batching — the stacked apply AND the
        data codec's own jit (q8's Pallas shapes) — so serving never pays a
        compile inside a measurement window.

        Serving pads bucket totals with ``_bucket_rows`` (pow2 over the
        summed rows), so the traced shapes are ``_bucket_rows(r * base)``
        for every request count r up to max_batch — not r-fold tilings,
        which would miss the padded shapes whenever ``base`` is not itself
        a power of two."""
        if self._apply is None or self._graph is None:
            return
        base: dict[str, np.ndarray] = {}
        for name in self._required:
            spec = (self._graph.input_spec if name == ""
                    else self._graph[name].out_spec)
            base[name] = np.zeros(spec.shape, np.dtype(spec.dtype))
        base_rows = next(iter(base.values())).shape[0]
        seen: set[int] = set()
        r = 1
        while r <= self.max_batch_cap:
            target = (_bucket_rows(r * base_rows) if self.pad_batches
                      else r * base_rows)
            r *= 2
            if target in seen:
                continue
            seen.add(target)
            reps = -(-target // base_rows)
            boundary = {k: jax.numpy.asarray(
                np.concatenate([v] * reps, axis=0)[:target] if reps > 1
                else v[:target])
                for k, v in base.items()}
            outs = self._apply(boundary)
            outs = {k: np.asarray(v) for k, v in outs.items()}
            blob, _ = self.data_codec.encode_tree(outs, "data")
            self.data_codec.decode_tree(blob)

    # -- inference step (paper §III-C) ----------------------------------------
    def start(self) -> None:
        if any(t.is_alive() for t in self._threads):
            return
        if self.staged:
            self._threads = [
                threading.Thread(target=self._ingress_loop, daemon=True),
                threading.Thread(target=self._compute_loop, daemon=True),
                threading.Thread(target=self._exit_clearing(self._egress_loop),
                                 daemon=True),
            ]
        else:
            self._threads = [
                threading.Thread(target=self._exit_clearing(self._legacy_loop),
                                 daemon=True)]
        for t in self._threads:
            t.start()

    def _exit_clearing(self, loop):
        """Wrap a replica's final pipeline stage so its exit — stop,
        retire, drain, or a dead link — releases the resident KV caches:
        an exited replica serves no further steps, and session recovery
        is re-prefill elsewhere, so the memory must not linger."""
        def run():
            try:
                loop()
            finally:
                self.sessions.clear()
        return run

    def stop(self) -> None:
        self.inbox.send(_STOP)
        self.join()

    def retire(self) -> None:
        """Queue the single-replica drain token (see ``_RETIRE``).  The
        caller fences routing first; everything already in this replica's
        queues still completes and relays before the threads exit."""
        self.inbox.send(_RETIRE)

    def join(self) -> None:
        for t in self._threads:
            t.join()

    def reset_stats(self) -> None:
        with self._stats_lock:
            self.traces = []
            self.queue_depths = []
            self._depth_sum = 0
            self._depth_count = 0
            self._trace_n = 0
            self._trace_compute_s = 0.0
            self._trace_serialize_s = 0.0
            self._trace_deserialize_s = 0.0
            self._trace_payload_bytes = 0
            self._trace_encodes = 0
            self.busy_decode_s = 0.0
            self.busy_compute_s = 0.0
            self.busy_encode_s = 0.0

    def _record_depth(self, depth: int) -> None:
        """Record one merge's queue-depth sample.  Caller holds
        ``_stats_lock``."""
        self.queue_depths.append(depth)
        self._depth_sum += depth
        self._depth_count += 1

    def _record_trace(self, trace: BatchTrace) -> None:
        """Append a finished batch's trace and fold it into the running
        totals.  Caller must hold ``_stats_lock``."""
        self.traces.append(trace)
        self._trace_n += trace.n
        self._trace_compute_s += trace.compute_s
        self._trace_serialize_s += trace.serialize_s
        self._trace_deserialize_s += trace.deserialize_s
        self._trace_payload_bytes += trace.payload_bytes
        self._trace_encodes += trace.encodes

    def snapshot(self) -> dict:
        """One consistent view of the current measurement window's
        telemetry — what the serving controller calibrates costs and
        adapts knobs from.  All time fields are window totals; ``n`` is
        requests computed this window.  O(1): reads the running totals,
        not the trace list."""
        with self._stats_lock:
            waves = len(self.traces)
            return {
                "node": self.index,
                "replica": self.replica,
                "n": self._trace_n,
                "compute_s": self._trace_compute_s,
                "serialize_s": self._trace_serialize_s,
                "deserialize_s": self._trace_deserialize_s,
                "payload_bytes": self._trace_payload_bytes,
                "encodes": self._trace_encodes,
                "busy_decode_s": self.busy_decode_s,
                "busy_compute_s": self.busy_compute_s,
                "busy_encode_s": self.busy_encode_s,
                "queue_depth_mean": (self._depth_sum / self._depth_count
                                     if self._depth_count else 0.0),
                "batch_mean": (self._trace_n / waves if waves else 0.0),
                # raw accumulators, so a delta-ing consumer (the
                # controller) can rebuild per-interval means instead of
                # mixing interval counters with window-cumulative gauges
                "waves": waves,
                "depth_sum": self._depth_sum,
                "depth_count": self._depth_count,
                "max_batch": self.max_batch,
                "coalesce_s": self.coalesce_s,
                "epoch": self.epoch,
                "inflight_n": self._inflight_n,
            }

    # -- stage 1: ingress (decode) --------------------------------------------
    def _ingress_loop(self) -> None:
        """Drain whatever is already queued (up to max_batch requests),
        decode each envelope once, and hand the whole wave to the compute
        stage — batches form *before* the slow decode, exactly where the
        backlog accumulates, so one wave becomes one apply and one encode."""
        while True:
            env = self._ingress_pending
            self._ingress_pending = None
            if env is None:
                try:
                    env = self.inbox.recv()
                except ChannelClosed:
                    # the inbound link died (socket reset / killed): this
                    # replica can never receive again, so it retires —
                    # everything already decoded flushes, nothing is
                    # signaled downstream (the router proxies its control
                    # tokens), and shutdown can still join its threads
                    self.retiring = True
                    self._to_compute.put(_RETIRE)
                    return
            if env is _STOP or env is _RETIRE:
                self._to_compute.put(env)
                return
            if isinstance(env, ReconfigMarker):
                # the epoch fence rides the FIFO: decode is partition-
                # independent, so ingress just relays it in order
                self._to_compute.put(env)
                continue
            wave = [env]
            n_parts = env.n if env.error is None else 0
            saw_stop = None
            deadline = None
            while n_parts < self.max_batch:
                try:
                    nxt = self.inbox.recv_nowait()
                except queue.Empty:
                    # downstream still chewing on the previous wave: a
                    # bounded coalescing window grows this wave instead of
                    # queueing a tiny one behind it (bigger waves = fewer
                    # codec passes; compute is busy so latency cost ~ 0)
                    if self._to_compute.qsize() == 0:
                        break
                    now = time.perf_counter()
                    if deadline is None:
                        deadline = now + self.coalesce_s
                    if now >= deadline:
                        break
                    try:
                        nxt = self.inbox.recv(timeout=deadline - now)
                    except queue.Empty:
                        continue
                    except ChannelClosed:
                        self.retiring = True
                        saw_stop = _RETIRE      # flush this wave, then exit
                        break
                except ChannelClosed:
                    self.retiring = True
                    saw_stop = _RETIRE
                    break
                if nxt is _STOP or nxt is _RETIRE:
                    saw_stop = nxt
                    break
                if isinstance(nxt, ReconfigMarker):
                    # close the wave at the fence; the marker leads the
                    # next iteration so it stays ordered behind this wave
                    self._ingress_pending = nxt
                    break
                if nxt.error is None and n_parts + nxt.n > self.max_batch:
                    # would overflow the batch contract (and the pow2
                    # specializations precompile() traced): next wave's
                    self._ingress_pending = nxt
                    break
                wave.append(nxt)
                if nxt.error is None:
                    n_parts += nxt.n
            # book only codec time as decode busy — the queue puts below can
            # block on backpressure, which is waiting, not stage work
            des_busy = 0.0
            decoded: list[_Decoded] = []
            relay: list[BatchEnvelope] = []
            for env in wave:
                if env.error is not None:       # relay failures untouched
                    relay.append(env)
                    continue
                t1 = time.perf_counter()
                try:
                    flat, _ = self.data_codec.decode_tree(env.blob)
                    dt = time.perf_counter() - t1
                    decoded.append(_Decoded(
                        env.extents,
                        {k: np.asarray(v) for k, v in flat.items()}, dt))
                except Exception:
                    dt = time.perf_counter() - t1
                    relay.append(BatchEnvelope(
                        env.extents, b"", error=traceback.format_exc()))
                des_busy += dt
            with self._stats_lock:
                self.busy_decode_s += des_busy
                self._inflight_n += sum(len(e.extents) for e in wave)
            for env in relay:
                self._to_compute.put(env)
            if decoded:
                self._to_compute.put(decoded)
            if saw_stop is not None:
                self._to_compute.put(saw_stop)
                return

    # -- stage 2: compute (merge, bucket, stack, apply) -----------------------
    def _compute_loop(self) -> None:
        while True:
            item = self._compute_pending
            self._compute_pending = None
            if item is None:
                item = self._to_compute.get()
            if item is _STOP or item is _RETIRE:
                self._to_encode.put(item)
                return
            if isinstance(item, ReconfigMarker):
                # the fence reached the compute stage: swap partitions NOW
                # (everything ahead of it already computed on the old one)
                self._apply_reconfig(item)
                self._to_encode.put(item)
                continue
            if isinstance(item, BatchEnvelope):  # error passthrough
                self._to_encode.put(item)
                continue
            # continuous batching, second chance: merge any further decoded
            # waves, up to max_batch requests, without waiting for arrivals
            group = list(item)
            n_parts = sum(len(d.extents) for d in group)
            saw_stop = None
            while n_parts < self.max_batch:
                try:
                    nxt = self._to_compute.get_nowait()
                except queue.Empty:
                    break
                if nxt is _STOP or nxt is _RETIRE:
                    saw_stop = nxt
                    break
                if isinstance(nxt, ReconfigMarker):
                    self._compute_pending = nxt    # fence: no merging across
                    break
                if isinstance(nxt, BatchEnvelope):
                    self._to_encode.put(nxt)
                    continue
                add = sum(len(d.extents) for d in nxt)
                if n_parts + add > self.max_batch:
                    self._compute_pending = nxt     # next merge's
                    break
                group.extend(nxt)
                n_parts += add
            with self._stats_lock:
                self._record_depth(n_parts + self.inbox.qsize()
                                   + self._to_compute.qsize())
            t0 = time.perf_counter()
            out, failures = self._compute_group(group)
            with self._stats_lock:
                self.busy_compute_s += time.perf_counter() - t0
            for env in failures:
                self._to_encode.put(env)
            if out is not None:
                self._to_encode.put(out)
            if saw_stop is not None:
                self._to_encode.put(saw_stop)
                return

    def _pad_to_bucket(self, d: _Decoded) -> _Decoded:
        """Zero-pad a decoded segment's middle axes up to the pow2 bucket
        sizes, recording each extent's ORIGINAL sizes the first time it is
        padded (later hops see already-pow2 shapes, so padding there is a
        no-op and the original trim is preserved).

        One ``pad_trim`` describes every leaf of the request, so a
        boundary whose leaves disagree on middle-axis sizes (e.g. a cut
        crossed by several pass-through activations) is left unpadded —
        it falls back to exact bucketing rather than risking a trim that
        slices real rows off a sibling leaf."""
        mids = {tuple(v.shape[1:-1]) for v in d.boundary.values()
                if v.ndim > 2}
        if len(mids) != 1:
            return d
        padded = {k: _pad_middle(v) for k, v in d.boundary.items()}
        if all(padded[k] is d.boundary[k] for k in padded):
            return d
        orig_mid = next(iter(mids))
        extents = [e if e.pad_trim is not None
                   else dataclasses.replace(e, pad_trim=orig_mid)
                   for e in d.extents]
        return _Decoded(extents, padded, d.deserialize_s)

    def _stack_apply(self, segments: list[dict[str, np.ndarray]],
                     total: int, target: int) -> tuple[dict[str, np.ndarray], float]:
        """Concatenate per-leaf segments along axis 0, zero-pad to ``target``
        rows, run the jitted partition apply once, trim back to ``total``.
        Shared by the staged compute stage and the legacy per-request path."""
        stacked: dict[str, jax.Array] = {}
        for key in segments[0]:
            arrs = [s[key] for s in segments]
            cat = np.concatenate(arrs, axis=0) if len(arrs) > 1 else arrs[0]
            if target > total:
                pad = np.zeros((target - total,) + cat.shape[1:], cat.dtype)
                cat = np.concatenate([cat, pad], axis=0)
            stacked[key] = jax.numpy.asarray(cat)
        t0 = time.perf_counter()
        res = self._apply(stacked)
        res = {k: np.asarray(v)[:total] for k, v in res.items()}  # block
        return res, time.perf_counter() - t0

    def _compute_group(self, group: list[_Decoded]
                       ) -> tuple[_Computed | None, list[BatchEnvelope]]:
        """Bucket decoded segments by signature, one stacked apply each.

        A bucket whose apply raises becomes an error envelope for exactly
        its own extents; sibling buckets in the merged group still return
        their results.

        With ``shape_buckets='pow2'``, near-miss trailing shapes are first
        zero-padded along their middle axes to the bucket's power-of-two
        sizes, so e.g. ragged sequence lengths merge into ONE apply instead
        of one bucket each; the original sizes ride the extents
        (``pad_trim``) and the tail collector trims them back out."""
        n = sum(len(d.extents) for d in group)
        des_s = sum(d.deserialize_s for d in group)
        # session frames (kind != K_PLAIN) take the decode path; plain
        # traffic keeps the stacked-apply path.  Both run inside the same
        # merged wave, so a chain can serve single-shot and decode traffic
        # simultaneously off one set of replicas.
        plain: list[_Decoded] = []
        sess: list[_Decoded] = []
        for d in group:
            (sess if any(e.kind != K_PLAIN for e in d.extents)
             else plain).append(d)
        outs: list[tuple[list[RowExtent], dict[str, np.ndarray]]] = []
        failures: list[BatchEnvelope] = []
        compute_total = 0.0
        padded_rows = 0
        if sess:
            s_out, s_fail, s_compute, s_padded = self._decode_group(sess)
            outs.extend(s_out)
            failures.extend(s_fail)
            compute_total += s_compute
            padded_rows += s_padded
        if self.shape_buckets == "pow2" and self._pad_safe:
            # only when every layer in this replica's slice is pad_safe:
            # a segment containing e.g. attention over the middle axis
            # would see padded positions, so it stays on exact bucketing
            plain = [self._pad_to_bucket(d) for d in plain]
        buckets: dict[tuple, list[_Decoded]] = {}
        for d in plain:
            buckets.setdefault(_signature(d.boundary), []).append(d)

        for segs in buckets.values():
            extents = [e for d in segs for e in d.extents]
            total = sum(next(iter(d.boundary.values())).shape[0]
                        for d in segs)
            target = _bucket_rows(total) if self.pad_batches else total
            padded_rows += target
            try:
                res, apply_s = self._stack_apply(
                    [d.boundary for d in segs], total, target)
            except Exception:
                failures.append(BatchEnvelope(extents, b"",
                                              error=traceback.format_exc()))
                continue
            compute_total += apply_s
            outs.append((extents, res))
        if not outs:
            return None, failures
        trace = BatchTrace(self.index, n, padded_rows, des_s, compute_total,
                           0.0, 0, encodes=0)
        return _Computed(outs, trace), failures

    def _decode_group(self, group: list[_Decoded]
                      ) -> tuple[list, list[BatchEnvelope], float, int]:
        """Serve one merged wave's session traffic (kind != K_PLAIN).

        Closes evict the session's resident caches and pass their payload
        through untouched (each stage on the way to the tail evicts in
        turn).  Opens run the slice's prefill individually (B=1 — jit
        specializes per prompt length) and park the resulting caches in
        this replica's :class:`SessionStore`; the tail stage trims its
        output to the last position so only one row of logits ships.
        Steps batch ACROSS sessions: per-session caches stack along the
        leading axis, positions ride per row, and ONE jitted step apply
        serves every session in the wave — continuous batching of decode
        at *different* sequence positions.  A step whose session has no
        resident cache here (evicted, repartitioned, replica restarted)
        fails with a ``SessionLost`` error envelope; recovery is the
        generate loop's re-prefill, never a replay.

        Session envelopes carry exactly one extent by protocol (routers
        pin whole envelopes; a multi-session envelope could not route
        sticky), enforced here.

        Returns ``(outs, failures, compute_s, padded_rows)`` for the
        caller's trace accounting.
        """
        outs: list[tuple[list[RowExtent], dict[str, np.ndarray]]] = []
        failures: list[BatchEnvelope] = []
        compute_s = 0.0
        padded = 0
        out_name = self._exported[0] if self._exported else ""
        steps: list[tuple[RowExtent, np.ndarray, Any]] = []
        for d in group:
            if len(d.extents) != 1:
                failures.append(BatchEnvelope(
                    d.extents, b"",
                    error="decode protocol violation: a session envelope "
                          "must carry exactly one extent"))
                continue
            e = d.extents[0]
            if e.kind == K_CLOSE:
                self.sessions.pop(e.session)
                outs.append(([e], d.boundary))
                continue
            if self._prefill_apply is None:
                failures.append(BatchEnvelope(
                    [e], b"",
                    error="SessionUnsupported: this partition has no "
                          "autoregressive view (the graph declares no "
                          "LayerDecode nodes, or the slice is not a "
                          "single-boundary chain)"))
                continue
            x = next(iter(d.boundary.values()))
            if e.kind == K_OPEN:
                t0 = time.perf_counter()
                try:
                    y, caches = self._prefill_apply(jax.numpy.asarray(x))
                    y = np.asarray(y)
                except Exception:
                    failures.append(BatchEnvelope(
                        [e], b"", error=traceback.format_exc()))
                    continue
                finally:
                    compute_s += time.perf_counter() - t0
                # park the caches even when the slice holds no stateful
                # layer (caches == {}): residency doubles as the routing
                # check a later step validates against
                self.sessions.put(e.session, caches)
                if self._is_tail:
                    y = y[:, -1:]
                padded += x.shape[0]
                outs.append(([e], {out_name: y}))
            elif e.kind == K_STEP:
                cache = self.sessions.get(e.session)
                if cache is None:
                    failures.append(BatchEnvelope([e], b"", error=(
                        f"SessionLost: stage {self.index} replica "
                        f"{self.replica} holds no KV cache for session "
                        f"{e.session!r} (evicted, repartitioned, or the "
                        "replica restarted); re-open the session from "
                        "its retained history")))
                    continue
                steps.append((e, np.asarray(x), cache))
            else:
                failures.append(BatchEnvelope(
                    [e], b"",
                    error=f"unknown session frame kind {e.kind}"))
        if steps:
            b = len(steps)
            target = _bucket_rows(b) if self.pad_batches else b
            # pad the batch by repeating the last row (token, position AND
            # caches): decode arithmetic is row-independent, so the real
            # rows are bit-identical to an unpadded apply and the padded
            # duplicates' outputs/caches are simply dropped
            rows = steps + [steps[-1]] * (target - b)
            xs = jax.numpy.asarray(
                np.concatenate([x for _, x, _ in rows], axis=0))
            pos = jax.numpy.asarray(
                np.asarray([e.pos for e, _, _ in rows], np.int32))
            caches = jax.tree_util.tree_map(
                lambda *leaves: jax.numpy.concatenate(leaves, axis=0),
                *[c for _, _, c in rows])
            t0 = time.perf_counter()
            try:
                y, new = self._decode_apply(caches, xs, pos)
                y = np.asarray(y)
            except Exception:
                compute_s += time.perf_counter() - t0
                tb = traceback.format_exc()
                failures.extend(BatchEnvelope([e], b"", error=tb)
                                for e, _, _ in steps)
                return outs, failures, compute_s, padded
            compute_s += time.perf_counter() - t0
            padded += target
            for i, (e, _, _) in enumerate(steps):
                self.sessions.put(e.session, jax.tree_util.tree_map(
                    lambda a, i=i: a[i:i + 1], new))
                outs.append(([e], {out_name: y[i:i + 1]}))
        return outs, failures, compute_s, padded

    # -- stage 3: egress (encode once per bucket, relay) ----------------------
    def _relay(self, item: Any) -> None:
        """Send one item downstream.

        A DEAD downstream link (socket reset) is swallowed: the item is
        lost either way — the chain is already severed past this hop —
        and an egress thread dying on the send would leave the internal
        queues undrained and deadlock shutdown on top of the network
        failure.  Any OTHER send failure (e.g. a payload the byte framing
        refuses) is per-batch: the envelope's extents travel on as an
        error envelope so the collector fails exactly those futures
        instead of the request silently hanging."""
        if self.next_inbox is None:
            return
        try:
            self.next_inbox.send(item)
        except (ChannelClosed, OSError):
            pass
        except Exception:
            if not isinstance(item, BatchEnvelope):
                return          # tokens/markers always frame: link fault
            try:
                self.next_inbox.send(BatchEnvelope(
                    item.extents, b"", error=traceback.format_exc(),
                    epoch=item.epoch))
            except Exception:  # deferlint: swallow(error envelope itself unencodable; no further signal possible)
                pass

    def _egress_loop(self) -> None:
        while True:
            item = self._to_encode.get()
            if item is _RETIRE:
                # single-replica drain: exit WITHOUT forwarding — the
                # downstream stage must not count a retired replica's stop
                return
            if item is _STOP:
                self._relay(_STOP)
                return
            if isinstance(item, ReconfigMarker):
                # epoch fence: everything encoded after this point was
                # computed on the new partition — stamp it so the next
                # stage's router can hold it behind its own fence barrier
                self._egress_epoch = item.epoch
                self._relay(item)
                continue
            if isinstance(item, BatchEnvelope):
                # error passthrough: relay in order, stamped
                item.epoch = self._egress_epoch
                with self._stats_lock:
                    self._inflight_n -= len(item.extents)
                self._relay(item)
                continue
            # book only codec time as encode busy; the relay puts can block
            # on the next node's bounded inbox (backpressure, not work)
            enc_busy = 0.0
            out_envs: list[BatchEnvelope] = []
            for extents, res in item.buckets:
                t0 = time.perf_counter()
                try:
                    blob, rec = self.data_codec.encode_tree(
                        res, "data", request_id=extents[0].request_id,
                        client_id=extents[0].client_id)
                    env = BatchEnvelope(extents, blob,
                                        epoch=self._egress_epoch)
                    item.trace.serialize_s += rec.encode_s
                    item.trace.payload_bytes += rec.wire_bytes
                    item.trace.encodes += 1
                except Exception:
                    env = BatchEnvelope(extents, b"",
                                        error=traceback.format_exc(),
                                        epoch=self._egress_epoch)
                enc_busy += time.perf_counter() - t0
                out_envs.append(env)
            with self._stats_lock:
                self.busy_encode_s += enc_busy
                self._record_trace(item.trace)
                self._inflight_n -= sum(len(e.extents) for e in out_envs)
            for env in out_envs:
                self._relay(env)

    # -- unstaged path (the PR 1 baseline, kept for A/B benchmarks) -----------
    def _legacy_loop(self) -> None:
        """Single worker thread: read -> decode -> apply -> encode PER
        REQUEST -> relay, the pre-staged hot path.  Kept so
        ``benchmarks/serve_load.py`` can measure the staged pipeline against
        the same-codec PR 1 baseline in one process."""
        while True:
            try:
                item = self.inbox.recv()
            except ChannelClosed:
                self.retiring = True     # dead inbound link: self-retire
                return
            if item is _RETIRE:
                return                   # drain this replica only: no relay
            if item is _STOP:
                self._relay(_STOP)
                return
            if isinstance(item, ReconfigMarker):
                self._apply_reconfig(item)
                self._egress_epoch = item.epoch
                self._relay(item)
                continue
            batch = [item]
            saw_stop = False
            retire = False
            marker = None
            while sum(e.n for e in batch) < self.max_batch:
                try:
                    nxt = self.inbox.recv_nowait()
                except queue.Empty:
                    break
                except ChannelClosed:
                    self.retiring = True
                    retire = True        # flush this batch, then exit
                    break
                if nxt is _STOP:
                    saw_stop = True
                    break
                if nxt is _RETIRE:
                    retire = True
                    break
                if isinstance(nxt, ReconfigMarker):
                    marker = nxt         # fence: swap after this batch
                    break
                batch.append(nxt)
            with self._stats_lock:
                self._record_depth(len(batch) + self.inbox.qsize())
                self._inflight_n += sum(len(e.extents) for e in batch)
            outs = self.process_batch(batch)
            with self._stats_lock:
                self._inflight_n -= sum(len(e.extents) for e in outs)
            for env in outs:
                env.epoch = self._egress_epoch
                self._relay(env)
            if marker is not None:
                self._apply_reconfig(marker)
                self._egress_epoch = marker.epoch
                self._relay(marker)
            if retire:
                return
            if saw_stop:
                self._relay(_STOP)
                return

    def process_batch(self, envs: list[BatchEnvelope]) -> list[BatchEnvelope]:
        """Decode, bucket-by-shape, pad, compute once, split, re-encode each
        request separately (per-request wire, PR 1 semantics)."""
        passthrough = [e for e in envs if e.error is not None]
        work = [e for e in envs if e.error is None]
        des_total = 0.0
        samples: list[tuple[RowExtent, dict[str, np.ndarray]]] = []
        failed: list[BatchEnvelope] = []
        for env in work:
            if any(ext.kind != K_PLAIN for ext in env.extents):
                # session residency needs the staged pipeline's sticky
                # decode path; the per-request legacy path has neither
                failed.append(BatchEnvelope(
                    env.extents, b"",
                    error="decode sessions require the staged runtime "
                          "(ComputeNode(staged=True))"))
                continue
            t0 = time.perf_counter()
            try:
                flat, _ = self.data_codec.decode_tree(env.blob)
                flat = {k: np.asarray(v) for k, v in flat.items()}
            except Exception:
                failed.append(BatchEnvelope(env.extents, b"",
                                            error=traceback.format_exc()))
                continue
            des_total += time.perf_counter() - t0
            for ext, part in zip(env.extents, slice_parts(flat, env.extents)):
                samples.append((ext, part))
        with self._stats_lock:
            self.busy_decode_s += des_total

        buckets: dict[tuple, list[tuple[RowExtent, dict]]] = {}
        for ext, boundary in samples:
            buckets.setdefault(_signature(boundary), []).append((ext, boundary))

        out_envs: list[BatchEnvelope] = list(passthrough) + failed
        compute_total = 0.0
        ser_total = 0.0
        payload_total = 0
        padded_rows = 0
        encodes = 0
        for bucket in buckets.values():
            rows = [next(iter(b.values())).shape[0] for _, b in bucket]
            total = sum(rows)
            target = _bucket_rows(total) if self.pad_batches else total
            padded_rows += target
            try:
                outs, apply_s = self._stack_apply(
                    [b for _, b in bucket], total, target)
                compute_total += apply_s
            except Exception:
                tb = traceback.format_exc()
                out_envs.extend(BatchEnvelope([ext], b"", error=tb)
                                for ext, _ in bucket)
                continue
            off = 0
            for (ext, _), b_rows in zip(bucket, rows):
                piece = {k: v[off:off + b_rows] for k, v in outs.items()}
                off += b_rows
                try:
                    t0 = time.perf_counter()
                    blob, rec = self.data_codec.encode_tree(
                        piece, "data", request_id=ext.request_id,
                        client_id=ext.client_id)
                    ser_total += time.perf_counter() - t0
                    payload_total += rec.wire_bytes
                    encodes += 1
                    out_envs.append(BatchEnvelope([ext], blob))
                except Exception:
                    out_envs.append(BatchEnvelope([ext], b"",
                                                  error=traceback.format_exc()))

        with self._stats_lock:
            self.busy_compute_s += compute_total
            self.busy_encode_s += ser_total
            self._record_trace(BatchTrace(
                self.index, len(samples), padded_rows, des_total,
                compute_total, ser_total, payload_total, encodes=encodes))
        return out_envs
