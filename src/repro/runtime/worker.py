"""Worker process entrypoint: one stage replica in its own OS process.

Run as ``python -m repro.runtime.worker --connect HOST:PORT --token T``.
The worker dials the supervisor's control listener, identifies itself
with the spawn token, and then follows a strictly serial control loop on
that socket:

* ``ControlFrame("config")`` — build the layer graph locally (the graph
  *code* is pre-installed on every device, exactly the paper's setting;
  only topology and weights travel), dial both data channels back into
  the supervisor's private :class:`~repro.runtime.transport.TcpTransport`
  listener (:func:`~repro.runtime.transport.dial_channel` — the worker
  never opens a listener of its own), and build the
  :class:`~repro.runtime.node.ComputeNode` this process serves.
* a framed :class:`~repro.runtime.wire.ReconfigMarker` — the
  configuration step: architecture spec + weights arrive over the wire
  (``NodePlan`` framing, same bytes a live repartition ships) and the
  node materializes its partition.
* ``"precompile"`` / ``"start"`` / ``"knobs"`` — lifecycle and tuning,
  applied in order (the loop is serial, so a ``"start"`` can never
  overtake the config that precedes it).  After ``"start"`` the worker
  acks ``"ready"`` and begins heartbeating.
* ``"chaos"`` — fault injection (hang the compute stage), honored only
  when the process was launched with ``--chaos``; production spawns
  ignore it.

Everything after ``"start"`` is the normal data path: envelopes and
fence markers arrive on the worker's inbox channel exactly as they would
on an in-process replica, so live repartitions, scale fences, and the
_STOP/_RETIRE drain protocol all work unchanged across the process
boundary.

When the node's stage threads exit (a clean drain: _STOP or a retire
fence flushed it), the worker sends ``"bye"`` on the control socket and
exits — that frame is how the supervisor distinguishes a deliberate
drain from a crash (a crash is control-EOF *without* bye, or a missed
heartbeat).  Every auxiliary thread is a daemon: the process can always
exit, whatever state the chain was in.
"""
from __future__ import annotations

import argparse
import importlib
import importlib.util
import os
import socket
import sys
import threading

from repro.runtime.node import ComputeNode
from repro.runtime.transport import dial_channel, recv_framed, send_framed
from repro.runtime.wire import (ControlFrame, ReconfigMarker, WireCodec,
                                WireFormatError)


def load_graph_factory(spec: str):
    """Resolve ``"pkg.module:fn"`` or ``"/path/to/file.py:fn"`` to the
    graph-factory callable.  The file-path form lets test helpers and
    benchmark scripts that are not importable packages supply graphs."""
    modpath, sep, fn_name = spec.rpartition(":")
    if not sep or not modpath or not fn_name:
        raise ValueError(
            f"bad graph factory {spec!r} (want 'module:fn' or 'file.py:fn')")
    if modpath.endswith(".py"):
        if not os.path.isfile(modpath):
            raise ImportError(f"graph module {modpath!r} does not exist")
        name = "_defer_worker_graph"
        loader_spec = importlib.util.spec_from_file_location(name, modpath)
        if loader_spec is None or loader_spec.loader is None:
            raise ImportError(f"cannot load graph module {modpath!r}")
        mod = importlib.util.module_from_spec(loader_spec)
        sys.modules[name] = mod
        loader_spec.loader.exec_module(mod)
    else:
        mod = importlib.import_module(modpath)
    return getattr(mod, fn_name)


class Worker:
    """The per-process runtime around one :class:`ComputeNode`."""

    def __init__(self, sock: socket.socket, allow_chaos: bool = False):
        self._sock = sock
        self._send_lock = threading.Lock()
        self._allow_chaos = allow_chaos
        self._node: ComputeNode | None = None
        self._graph = None
        self._stage = -1
        self._hb_interval_s = 0.5
        self._stop = threading.Event()

    def _send(self, frame: ControlFrame) -> None:
        send_framed(self._sock, frame, lock=self._send_lock)

    # -- control handlers -----------------------------------------------------
    def _on_config(self, p: dict) -> None:
        factory = load_graph_factory(p["graph_factory"])
        self._graph = factory(**(p.get("graph_args") or {}))
        # 4-element form predates the small-payload bypass: default it off
        ser, comp, rate, vec = p["data_codec"][:4]
        bypass = p["data_codec"][4] if len(p["data_codec"]) > 4 else 0
        codec = WireCodec(ser, comp, zfp_rate=rate, vectorized=vec,
                          small_bypass=bypass)
        host, port = p["host"], p["port"]
        self._stage = p["stage"]
        self._hb_interval_s = float(p.get("heartbeat_s", 0.5))
        inbox = dial_channel(host, port, p["in_cid"], role="recv",
                             capacity=p["in_capacity"])
        try:
            out = dial_channel(host, port, p["out_cid"], role="send",
                               capacity=p["out_capacity"])
        except BaseException:
            # the second dial failed: the first socket must not outlive
            # the config attempt (the supervisor will tear down and
            # respawn; a dangling dialed channel would hold its accept
            # slot forever)
            inbox.close()
            raise
        try:
            node = ComputeNode(
                p["stage"], codec, replica=p["replica"],
                max_batch=p["max_batch"], staged=p.get("staged", True),
                shape_buckets=p.get("shape_buckets", "exact"),
                max_batch_cap=p.get("max_batch_cap"),
                session_capacity=p.get("session_capacity", 64) or 64,
                inbox=inbox)
            node.coalesce_s = float(p["coalesce_s"])
            node.next_inbox = out
        except BaseException:
            inbox.close()
            out.close()
            raise
        self._node = node

    def _on_knobs(self, p: dict) -> None:
        node = self._node
        if node is None:
            return
        if "max_batch" in p:
            node.max_batch = min(max(1, int(p["max_batch"])),
                                 node.max_batch_cap)
        if "coalesce_s" in p:
            node.coalesce_s = max(0.0, float(p["coalesce_s"]))

    def _on_chaos(self, p: dict) -> None:
        if not self._allow_chaos:
            return          # fault injection is opt-in at spawn time
        if p.get("action") == "hang_compute":
            # replace the jitted apply with a wait that never completes:
            # the compute stage wedges mid-batch while every OTHER thread
            # (ingress, heartbeat, control) stays perfectly healthy — the
            # scenario heartbeat-only detection must NOT page on, and
            # stall detection (snapshot frozen + inbox backlog) must
            hang = threading.Event()
            self._node._apply = lambda *_a, **_k: hang.wait()
        elif p.get("action") == "slow_compute":
            # dilate each apply by a host-side sleep: batches dwell in
            # compute long enough for chaos tests to land a SIGKILL
            # reliably *mid-batch*, and for slow-but-alive workers to
            # exercise the no-false-positive side of failure detection
            delay = float(p.get("delay_s", 0.05))
            orig = self._node._apply
            pause = threading.Event()
            self._node._apply = (lambda *a, _o=orig, **k:
                                 (pause.wait(delay), _o(*a, **k))[1])

    def _on_start(self) -> None:
        self._node.start()
        threading.Thread(target=self._heartbeat_loop, daemon=True).start()
        threading.Thread(target=self.drain, daemon=True).start()
        self._send(ControlFrame("ready", {"pid": os.getpid()}))

    # -- background threads ---------------------------------------------------
    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self._hb_interval_s):
            try:
                self._send(ControlFrame(
                    "hb", {"snapshot": self._node.snapshot()}))
            except OSError:
                return      # control stream gone: the supervisor owns cleanup

    def drain(self) -> None:
        """Wait for the node's stage threads to exit — a clean flush via
        _STOP or a retire fence — then send the deliberate ``"bye"`` and
        unblock the main control loop so the process exits zero."""
        self._node.join()
        self._stop.set()
        try:
            self._send(ControlFrame("bye", {}))
        except OSError:
            pass            # supervisor already gone; exiting is enough
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass            # racing close: the control loop is done anyway

    # -- the serial control loop ----------------------------------------------
    def run(self) -> int:
        while True:
            try:
                item = recv_framed(self._sock)
            except (WireFormatError, OSError):
                # control EOF: a drained worker already sent bye; anything
                # else means the supervisor died — either way, exit (all
                # other threads are daemons)
                return 0
            if isinstance(item, ReconfigMarker):
                # the configuration step: the initial partition arrives as
                # the same NodePlan framing a live repartition ships
                plan = item.plans.get(self._stage)
                if plan is not None and self._node is not None:
                    self._node.configure(
                        self._graph, plan.lo, plan.hi, plan.arch_blob,
                        plan.weights_blob, plan.weights_codec)
                continue
            if not isinstance(item, ControlFrame):
                continue
            if item.kind == "config":
                self._on_config(item.payload)
            elif item.kind == "precompile":
                self._node.precompile()
            elif item.kind == "start":
                self._on_start()
            elif item.kind == "knobs":
                self._on_knobs(item.payload)
            elif item.kind == "chaos":  # deferlint: control-verb(sent by the tools/chaos.py harness, not the supervisor)
                self._on_chaos(item.payload)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.worker",
        description="DEFER stage-replica worker (spawned by the "
                    "runtime supervisor; not usually run by hand)")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="the supervisor's control listener")
    ap.add_argument("--token", default="",
                    help="spawn token identifying this replica slot")
    ap.add_argument("--chaos", action="store_true",
                    help="honor ControlFrame('chaos') fault injection")
    args = ap.parse_args(argv)
    host, _, port = args.connect.rpartition(":")
    sock = socket.create_connection((host, int(port)), timeout=10.0)
    try:
        # the timeout covers CONNECTING only: left on the socket it would
        # turn any 10s-quiet control stream into a TimeoutError in the recv
        # loop — read as "supervisor died", exiting a perfectly healthy
        # worker
        sock.settimeout(None)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        worker = Worker(sock, allow_chaos=args.chaos)
        send_framed(sock, ControlFrame(
            "hello", {"token": args.token, "pid": os.getpid()}))
    except BaseException:
        sock.close()
        raise
    return worker.run()


if __name__ == "__main__":
    raise SystemExit(main())
