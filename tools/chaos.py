"""Fault-injection harness for supervised process-per-replica serving.

Wraps a :class:`~repro.runtime.supervisor.Supervisor` with the three
fault primitives the chaos tests (and any manual resilience drill) need:

* :meth:`Chaos.kill` — SIGKILL a worker process (the paper's "device
  died" case: no goodbye, batches in its pipeline are simply gone);
* :meth:`Chaos.hang_compute` / :meth:`Chaos.slow_compute` — wedge or
  dilate a worker's compute stage *while its heartbeat stays healthy*
  (the failure mode liveness-by-heartbeat cannot see, and the one
  stall detection exists for);
* :meth:`Chaos.sever` — kill a worker's data sockets mid-batch while
  the process itself stays up (a flaky link, not a dead device).

Plus event-log helpers (:meth:`wait_event`) so tests assert on the
supervisor's audit trail — "a death was recorded, then a respawn" —
instead of sleeping and hoping.  ``hang``/``slow``/chaos frames require
the workers to have been spawned with ``--chaos``
(``SupervisorConfig(allow_chaos=True)``); production spawns ignore them.
"""
from __future__ import annotations

import os
import signal
import threading
import time

from repro.runtime.wire import ControlFrame


class Chaos:
    """Fault injector bound to one supervisor."""

    def __init__(self, supervisor):
        self.sup = supervisor
        self._tick = threading.Event()

    # -- victim selection ------------------------------------------------------
    def workers(self, stage: int | None = None) -> list:
        """Live (non-dead, spawned) worker handles, optionally one stage's."""
        with self.sup._lock:
            handles = list(self.sup._handles)
        return [h for h in handles
                if not h.dead and h.proc is not None
                and h.proc.poll() is None
                and (stage is None or h.index == stage)]

    def pick(self, stage: int | None = None):
        """First live worker (of ``stage``); raises if none survive."""
        victims = self.workers(stage)
        if not victims:
            raise LookupError(f"no live worker to target (stage={stage})")
        return victims[0]

    # -- fault primitives ------------------------------------------------------
    def kill(self, handle) -> int:
        """SIGKILL the worker: no drain, no goodbye, batches inside its
        pipeline are lost.  Returns the victim pid."""
        pid = handle.proc.pid
        os.kill(pid, signal.SIGKILL)
        return pid

    def hang_compute(self, handle) -> None:
        """Wedge the worker's compute stage forever.  Its heartbeat
        thread stays perfectly healthy — only OS reaping won't fire and
        only stall detection can page."""
        handle._control_send(
            ControlFrame("chaos", {"action": "hang_compute"}), required=True)

    def slow_compute(self, handle, delay_s: float = 0.05) -> None:
        """Dilate every apply by ``delay_s`` — a slow-but-alive worker
        (kills must land mid-batch; failure detection must NOT page)."""
        handle._control_send(
            ControlFrame("chaos", {"action": "slow_compute",
                                   "delay_s": delay_s}), required=True)

    def hang_stage(self, stage: int) -> int:
        """Wedge EVERY live worker of one stage (no healthy sibling to
        route around — the deadline drills need the whole stage dark).
        Returns how many workers were hung."""
        victims = self.workers(stage)
        for h in victims:
            self.hang_compute(h)
        return len(victims)

    def slow_stage(self, stage: int, delay_s: float = 0.05) -> int:
        """Dilate every live worker of one stage (kills land mid-batch)."""
        victims = self.workers(stage)
        for h in victims:
            self.slow_compute(h, delay_s)
        return len(victims)

    def sever(self, handle) -> None:
        """Cut the worker's data sockets mid-batch, process left running:
        a dead link, not a dead device.  The routers see a dead channel
        and heal exactly as for a crash; the supervisor's monitor then
        reaps the orphaned process when its heartbeat socket dies or the
        stage respawns over it."""
        handle.kill_links()

    # -- event-log assertions --------------------------------------------------
    def events(self, kind: str | None = None,
               stage: int | None = None) -> list[dict]:
        with self.sup._lock:
            evs = list(self.sup.events)
        return [e for e in evs
                if (kind is None or e["kind"] == kind)
                and (stage is None or e.get("stage") == stage)]

    def wait_event(self, kind: str, stage: int | None = None,
                   count: int = 1, timeout: float = 30.0) -> list[dict]:
        """Block until the supervisor's audit trail holds ``count``
        events of ``kind`` (for ``stage``), or raise TimeoutError with
        the trail so far — chaos tests assert on recorded facts, not on
        sleeps."""
        deadline = time.monotonic() + timeout
        while True:
            got = self.events(kind, stage)
            if len(got) >= count:
                return got
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"no {count}x {kind!r} (stage={stage}) within "
                    f"{timeout}s; events so far: "
                    f"{[e['kind'] for e in self.events()]}")
            self._tick.wait(0.05)

    def wait_respawn(self, stage: int, count: int = 1,
                     timeout: float = 30.0) -> list[dict]:
        return self.wait_event("respawn", stage, count, timeout)

    def wait_death(self, stage: int, count: int = 1,
                   timeout: float = 30.0) -> list[dict]:
        return self.wait_event("death", stage, count, timeout)

    def wait_stage_full(self, dispatcher, stage: int,
                        timeout: float = 30.0) -> int:
        """Block until ``stage`` is back to its topology target replica
        count (post-respawn convergence)."""
        deadline = time.monotonic() + timeout
        while True:
            target = dispatcher.topology.stages[stage].replicas
            live = [r for r in dispatcher.stages[stage].live_replicas()
                    if not r.retiring]
            if len(live) >= target:
                return len(live)
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"stage {stage} stuck at {len(live)}/{target} replicas")
            self._tick.wait(0.05)
