"""Intraprocedural control-flow graphs for deferlint's flow rules.

PR 6's rules are lexical: they look at what a function *mentions*, not at
which paths it can take.  Every hard bug this repo has shipped, though,
lived on a *path* — an except arm that dropped a dequeued future, an
early raise that skipped a channel close.  This module builds the small
CFG the flow rules (DL601/DL602) walk.

The graph is statement-level: one node per ``ast.stmt``, plus three
synthetic nodes — ``ENTRY``, ``EXIT`` (a ``return`` or falling off the
end) and ``RAISE`` (an exception escapes the function).  Edges carry a
kind tag:

* ``"seq"``   — ordinary fallthrough
* ``"true"`` / ``"false"`` — the two arms of an ``if``/loop test
* ``"exc"``   — the statement raised

Exception edges are deliberately scoped: a can-raise statement inside a
``try`` gets exc edges to the handler entries *only* (an uncaught-type
escape through a narrow handler is out of scope — modeling it would flag
every guarded cleanup in the repo).  A can-raise statement outside any
``try`` gets an exc edge to ``RAISE``.  ``finally`` bodies are threaded
on the normal path and reachable from exception edges; the
exception-propagates-after-finally continuation is approximated by a
direct edge to the outer target (the union over-approximates both real
paths, which is all the leak query needs).

Two value-sensitivity crumbs keep the common runtime idioms clean
without a real dataflow lattice, both implemented in :func:`find_leak`:

* ``x = d.pop(k, None)`` followed by ``if x is None:`` — the None arm
  carries no obligation, so that edge is pruned.
* rebinding the tracked name kills the obligation (the loop back-edge in
  ``for ...: x = q.pop(...)`` starts a *new* obligation, analyzed from
  its own acquisition site).
"""

from __future__ import annotations

import ast
from typing import Callable, Dict, List, Optional, Sequence, Tuple

ENTRY = 0
EXIT = 1    # normal exit: return, or falling off the end of the body
RAISE = 2   # an exception escapes the function

# Method names whose calls are treated as non-raising.  These are the
# runtime's cleanup/release vocabulary: without the carve-out, a handler
# that closes two resources in sequence would grow an exc edge out of
# the first close and the second resource would look leakable.
_RELEASEY = {
    "close", "kill", "shutdown", "cancel", "set_result", "set_exception",
    "unexpect_channel", "pop", "discard", "clear", "release",
}


def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _expr_raises(e: Optional[ast.expr]) -> bool:
    if e is None:
        return False
    for node in ast.walk(e):
        if isinstance(node, ast.Call) and _call_name(node) not in _RELEASEY:
            return True
        if isinstance(node, ast.Subscript):
            return True
    return False


def _stmt_raises(s: ast.stmt) -> bool:
    if isinstance(s, ast.Assert):
        return True
    for node in ast.walk(s):
        if isinstance(node, ast.Call) and _call_name(node) not in _RELEASEY:
            return True
        if isinstance(node, ast.Subscript):
            return True
    return False


class CFG:
    """CFG for one function body.  ``succ[n]`` is ``[(node, kind), ...]``;
    ``stmt[n]`` maps back to the ``ast.stmt``; ``node_of[id(stmt)]``
    resolves a statement object to its node.  Nested function bodies are
    *not* inlined — a nested ``def`` is a single opaque statement here
    and gets its own CFG when the caller iterates functions."""

    def __init__(self, fn: ast.AST):
        self.fn = fn
        self.succ: Dict[int, List[Tuple[int, str]]] = {
            ENTRY: [], EXIT: [], RAISE: []}
        self.stmt: Dict[int, ast.stmt] = {}
        self.node_of: Dict[int, int] = {}
        self._n = 3
        body = getattr(fn, "body", [])
        entry = self._seq(body, EXIT, None, None, (RAISE,))
        self.succ[ENTRY].append((entry, "seq"))

    # -- construction ----------------------------------------------------------
    def _new(self, s: ast.stmt) -> int:
        n = self._n
        self._n += 1
        self.stmt[n] = s
        self.node_of[id(s)] = n
        self.succ[n] = []
        return n

    def _edge(self, a: int, b: int, kind: str) -> None:
        self.succ[a].append((b, kind))

    def _exc(self, n: int, excs: Tuple[int, ...]) -> None:
        for t in excs:
            self._edge(n, t, "exc")

    def _seq(self, body: Sequence[ast.stmt], nxt: int,
             brk: Optional[int], cont: Optional[int],
             excs: Tuple[int, ...]) -> int:
        entry = nxt
        for s in reversed(body):
            entry = self._stmt(s, entry, brk, cont, excs)
        return entry

    def _stmt(self, s: ast.stmt, nxt: int, brk: Optional[int],
              cont: Optional[int], excs: Tuple[int, ...]) -> int:
        n = self._new(s)
        if isinstance(s, ast.Return):
            self._edge(n, EXIT, "seq")
            if _expr_raises(s.value):
                self._exc(n, excs)
        elif isinstance(s, ast.Raise):
            self._exc(n, excs)
        elif isinstance(s, ast.Break):
            self._edge(n, brk if brk is not None else EXIT, "seq")
        elif isinstance(s, ast.Continue):
            self._edge(n, cont if cont is not None else EXIT, "seq")
        elif isinstance(s, ast.If):
            self._edge(n, self._seq(s.body, nxt, brk, cont, excs), "true")
            self._edge(n, self._seq(s.orelse, nxt, brk, cont, excs), "false")
            if _expr_raises(s.test):
                self._exc(n, excs)
        elif isinstance(s, ast.While):
            self._edge(n, self._seq(s.body, n, nxt, n, excs), "true")
            infinite = isinstance(s.test, ast.Constant) and bool(s.test.value)
            if not infinite:
                self._edge(n, self._seq(s.orelse, nxt, brk, cont, excs),
                           "false")
            if _expr_raises(s.test):
                self._exc(n, excs)
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            self._edge(n, self._seq(s.body, n, nxt, n, excs), "true")
            self._edge(n, self._seq(s.orelse, nxt, brk, cont, excs), "false")
            if _expr_raises(s.iter):
                self._exc(n, excs)
        elif isinstance(s, ast.Try):
            self._try(n, s, nxt, brk, cont, excs)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            self._edge(n, self._seq(s.body, nxt, brk, cont, excs), "seq")
            if any(_expr_raises(it.context_expr) for it in s.items):
                self._exc(n, excs)
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            self._edge(n, nxt, "seq")
        else:
            self._edge(n, nxt, "seq")
            if _stmt_raises(s):
                self._exc(n, excs)
        return n

    def _try(self, n: int, s: ast.Try, nxt: int, brk: Optional[int],
             cont: Optional[int], excs: Tuple[int, ...]) -> None:
        if s.finalbody:
            fin = self._seq(s.finalbody, nxt, brk, cont, excs)
            after = fin
            outer = (fin,) + tuple(excs)
        else:
            after = nxt
            outer = tuple(excs)
        handler_entries = tuple(
            self._seq(h.body, after, brk, cont, outer) for h in s.handlers)
        body_tail = (self._seq(s.orelse, after, brk, cont, outer)
                     if s.orelse else after)
        body_exc = handler_entries if handler_entries else outer
        self._edge(n, self._seq(s.body, body_tail, brk, cont, body_exc),
                   "seq")


def _rebinds(s: ast.stmt, name: str) -> bool:
    """Does this statement rebind ``name``?  A rebind ends the tracked
    obligation (the new value gets its own analysis from its own site)."""
    targets: List[ast.expr] = []
    if isinstance(s, ast.Assign):
        targets = list(s.targets)
    elif isinstance(s, (ast.AnnAssign, ast.AugAssign)):
        targets = [s.target]
    elif isinstance(s, (ast.For, ast.AsyncFor)):
        targets = [s.target]
    elif isinstance(s, (ast.With, ast.AsyncWith)):
        targets = [it.optional_vars for it in s.items if it.optional_vars]
    for t in targets:
        for node in ast.walk(t):
            if isinstance(node, ast.Name) and node.id == name:
                return True
    return False


def _none_polarity(test: ast.expr, name: str) -> Optional[str]:
    """Which arm of ``if <test>:`` means ``name is None``?  Returns
    ``"true"``, ``"false"``, or None when the test says nothing about
    ``name``'s None-ness."""
    if isinstance(test, ast.Compare) and len(test.ops) == 1:
        left, right = test.left, test.comparators[0]
        if (isinstance(left, ast.Name) and left.id == name
                and isinstance(right, ast.Constant) and right.value is None):
            if isinstance(test.ops[0], ast.Is):
                return "true"
            if isinstance(test.ops[0], ast.IsNot):
                return "false"
    if (isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not)
            and isinstance(test.operand, ast.Name)
            and test.operand.id == name):
        return "true"
    if isinstance(test, ast.Name) and test.id == name:
        return "false"
    return None


def find_leak(cfg: CFG, acquisition: ast.stmt, name: str,
              is_release: Callable[[ast.stmt, str], bool],
              raise_is_leak: bool) -> Optional[str]:
    """Walk forward from ``acquisition`` looking for a path on which the
    obligation on ``name`` is never discharged.  ``is_release(stmt,
    name)`` decides whether a statement discharges it (a release call, a
    hand-off into a tracked sink, a return).  Returns a short description
    of the leaking exit, or None when every path discharges.

    Exploration stops at a releasing statement *before* following its
    out-edges ("absorb on visit"): storing the resource into a registry
    discharges even though the store itself could raise afterwards.
    Exception edges out of the acquisition statement itself are skipped —
    if the acquiring call raised, nothing was ever bound."""
    start = cfg.node_of.get(id(acquisition))
    if start is None:
        return None
    stack = [dst for dst, kind in cfg.succ.get(start, ()) if kind != "exc"]
    seen = set()
    while stack:
        node = stack.pop()
        if node == EXIT:
            return "reaches a normal exit"
        if node == RAISE:
            if raise_is_leak:
                return "escapes on an exception path"
            continue
        if node in seen:
            continue
        seen.add(node)
        s = cfg.stmt[node]
        if _rebinds(s, name):
            continue
        if is_release(s, name):
            continue
        polarity = (_none_polarity(s.test, name)
                    if isinstance(s, ast.If) else None)
        for dst, kind in cfg.succ.get(node, ()):
            if polarity is not None and kind == polarity:
                continue    # this edge means `name is None`: no obligation
            stack.append(dst)
    return None
