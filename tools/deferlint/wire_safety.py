"""DL101/DL102/DL103 — wire-safety.

DL101: every ``struct.unpack`` / ``struct.unpack_from`` must be preceded,
lexically within the same function, by a call to the ``_checked`` bounds
gate (``wire._checked`` or a local ``_checked``) covering the read.  The
check is deliberately lexical, not dataflow: the runtime's convention is
"call ``_checked(blob, off, n, what)`` on the line(s) right before the
unpack", and the lint enforces that the convention is followed, not that
arbitrary bounds logic is correct.  Sites that cannot follow the
convention go in ``ALLOWLIST`` — currently only ``core/codecs.py``
internals, whose sole callers (``wire.decode_array`` et al.) already wrap
every decode error into ``WireFormatError``.

DL102: ``pickle``/``marshal`` imports and ``eval``/``exec`` calls are
banned in ``runtime/`` — nothing on the wire path may deserialize
arbitrary objects or execute strings.

DL103: ``time.time()`` is banned in ``runtime/`` — deadlines, backoff,
heartbeat ages, and every other duration the runtime computes must use
``time.monotonic()`` (or ``time.perf_counter()`` for fine timing): a
wall-clock step (NTP slew, manual set, DST on a naive host) must never
expire a deadline early or freeze a backoff.  Wall-clock timestamps for
logs/audit trails belong OUTSIDE ``runtime/`` (the supervisor's event
log uses monotonic ages; benchmark emitters live in ``benchmarks/``).
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Set, Tuple

from tools.deferlint.core import (
    ModuleInfo, Violation, checker, enclosing_function_map,
)

# (relpath suffix, enclosing-function qualname) pairs exempt from DL101.
# Bar for adding an entry: the function is unreachable except through a
# caller that already converts struct.error into WireFormatError, and the
# buffer geometry is validated by that caller.
ALLOWLIST: Set[Tuple[str, str]] = {
    ("core/codecs.py", "_unpack_shape_dtype"),
    ("core/codecs.py", "ZfpCodec.decode"),
    ("core/codecs.py", "Lz4Codec.decompress"),
    ("core/codecs.py", "Q8Codec.decode"),
}


def _is_checked_call(node: ast.Call) -> bool:
    f = node.func
    if isinstance(f, ast.Name) and f.id == "_checked":
        return True
    if isinstance(f, ast.Attribute) and f.attr == "_checked":
        return True
    return False


@checker("wire-safety", rules={
    "DL101": "struct.unpack/unpack_from not behind wire._checked "
             "(allowlist: core/codecs.py internals only)",
    "DL102": "pickle/marshal import or eval/exec call in runtime/ or the "
             "tools/benchmarks toolchain",
    "DL103": "time.time() inside runtime/ (deadlines/backoff must use "
             "time.monotonic or perf_counter)",
})
def check(mods: List[ModuleInfo]) -> Iterable[Violation]:
    for mi in mods:
        yield from _check_unpacks(mi)
        if mi.in_runtime or mi.in_toolchain:
            yield from _check_banned(mi)


def _check_unpacks(mi: ModuleInfo) -> Iterable[Violation]:
    encl = enclosing_function_map(mi.tree)
    # gather per-function lists of (_checked lineno) and (unpack node)
    checked_lines: dict = {}
    unpacks: List[Tuple[str, ast.Call]] = []
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        where = encl.get(node)
        qn = where[0] if where else "<module>"
        if _is_checked_call(node):
            checked_lines.setdefault(qn, []).append(node.lineno)
        else:
            f = node.func
            is_unpack = (
                isinstance(f, ast.Attribute)
                and f.attr in ("unpack", "unpack_from")
                and isinstance(f.value, ast.Name)
                and f.value.id == "struct"
            ) or (
                isinstance(f, ast.Name)
                and f.id in ("unpack", "unpack_from")
            )
            if is_unpack:
                unpacks.append((qn, node))
    for qn, node in unpacks:
        if (_suffix_key(mi.relpath), qn) in ALLOWLIST:
            continue
        before = [ln for ln in checked_lines.get(qn, []) if ln <= node.lineno]
        if before:
            continue
        yield Violation(
            "DL101", mi.relpath, node.lineno,
            f"struct.{node.func.attr if isinstance(node.func, ast.Attribute) else 'unpack'} "
            f"in {qn} has no preceding _checked() bounds gate "
            "(route through wire._checked or add an ALLOWLIST entry)",
        )


def _suffix_key(relpath: str) -> str:
    parts = relpath.split("/")
    return "/".join(parts[-2:]) if len(parts) >= 2 else relpath


def _check_banned(mi: ModuleInfo) -> Iterable[Violation]:
    # DL102 applies to the whole hygiene scope (runtime/ + tools/ +
    # benchmarks/); DL103's monotonic-clock discipline is runtime-only
    # (benchmark emitters legitimately stamp wall-clock times).
    for node in ast.walk(mi.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                root = alias.name.split(".")[0]
                if root in ("pickle", "marshal"):
                    yield Violation(
                        "DL102", mi.relpath, node.lineno,
                        f"import of {root!r} (wire payloads must use the "
                        "framed codec path, never object pickling)",
                    )
        elif isinstance(node, ast.ImportFrom):
            root = (node.module or "").split(".")[0]
            if root in ("pickle", "marshal"):
                yield Violation(
                    "DL102", mi.relpath, node.lineno,
                    f"import from {root!r}",
                )
        elif isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in ("eval", "exec"):
                yield Violation(
                    "DL102", mi.relpath, node.lineno,
                    f"{f.id}() call",
                )
            elif (mi.in_runtime
                    and isinstance(f, ast.Attribute) and f.attr == "time"
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "time"):
                yield Violation(
                    "DL103", mi.relpath, node.lineno,
                    "time.time() in runtime/ — wall clock jumps break "
                    "deadlines/backoff; use time.monotonic() (or "
                    "time.perf_counter() for fine timing)",
                )
