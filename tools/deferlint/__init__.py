"""deferlint — repo-specific static analysis + runtime concurrency harnesses.

Run: ``python -m tools.deferlint src``
"""

from tools.deferlint.core import (  # noqa: F401
    RULE_CATALOG, ModuleInfo, Violation, lint_paths, main,
)
from tools.deferlint.core import _load_checkers as _load

# populate RULE_CATALOG (same dict object) from the checker registry so
# `from tools.deferlint import RULE_CATALOG` is complete without a lint run
_load()
del _load
