"""deferlint — repo-specific static analysis + runtime concurrency harnesses.

Run: ``python -m tools.deferlint src``
"""

from tools.deferlint.core import (  # noqa: F401
    RULE_CATALOG, ModuleInfo, Violation, lint_paths, main,
)
