"""DL601/DL602 — flow-sensitive future-resolution and resource lifecycle.

Both rules walk the :mod:`tools.deferlint.cfg` graph from each
acquisition site and demand that every path discharges the obligation:

DL601: a ``runtime/`` function that creates a ``Future()`` or dequeues
one from a futures container (``*.pop``/``popleft``/... on a receiver
whose name mentions ``futur``) must, on every path that completes
normally, resolve it (``set_result``/``set_exception``/``cancel``), pass
it to a call (the sequenced-merge resolver, a fan-out helper), store it
into a tracked sink (pending map, retention ledger, hold buffer), or
return it.  Paths that *raise* are acceptable — the caller still owns
whatever registered the future — but an ``except`` arm that swallows and
falls through without resolving is exactly the bug class this rule
exists for.

DL602: every channel/socket/session-store acquisition
(``transport.channel(...)``, ``expect_channel``, ``dial_channel``,
``socket.socket``/``create_connection``/``accept``, ``SessionStore``)
must reach a release (``close``/``kill``/``shutdown``/...), a hand-off
(call argument, store into an owner attribute/registry, return) on
**all** exits — including exception paths: an early raise that skips the
close is a leak, because unlike a future there is no caller-side
registration to fall back on.

Suppression (the bar is "a reviewer agreed ownership is genuinely
transferred in a way the analysis cannot see"): ``# deferlint:
resolved-by(<owner>)`` on the acquisition line.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List, Optional

from tools.deferlint.cfg import CFG, find_leak
from tools.deferlint.core import (
    ModuleInfo, Violation, checker, iter_functions,
)

RESOLVED_RE = re.compile(r"#\s*deferlint:\s*resolved-by\(([^)]+)\)")

_FUTURE_CONTAINER = re.compile(r"futur", re.IGNORECASE)
_DEQUEUE_METHODS = {"pop", "popleft", "popitem", "get_nowait"}
_FUT_RESOLVE = {"set_result", "set_exception", "cancel"}

_RES_RELEASE = {"close", "kill", "shutdown", "detach", "stop", "release"}
_RES_ACQ_FUNCS = {"channel", "expect_channel", "dial_channel",
                  "create_connection", "accept", "SessionStore"}

_COMPOUND = (ast.If, ast.While, ast.For, ast.AsyncFor, ast.With,
             ast.AsyncWith, ast.Try, ast.FunctionDef, ast.AsyncFunctionDef,
             ast.ClassDef)


def _contains_name(node: Optional[ast.AST], name: str) -> bool:
    if node is None:
        return False
    return any(isinstance(n, ast.Name) and n.id == name
               for n in ast.walk(node))


def _name_in_args(call: ast.Call, name: str) -> bool:
    for a in call.args:
        if _contains_name(a, name):
            return True
    for kw in call.keywords:
        if _contains_name(kw.value, name):
            return True
    return False


def _handed_off(s: ast.stmt, name: str, methods: set) -> bool:
    """Shared discharge predicate: a method-on-name call from ``methods``,
    name passed to any call, name stored through an attribute/subscript
    target or aliased, or name returned/yielded/raised."""
    if isinstance(s, _COMPOUND):
        # compound statements' bodies are their own CFG nodes; the header
        # expression (a test / iterable) never discharges an obligation
        return False
    for node in ast.walk(s):
        if isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr in methods
                    and isinstance(f.value, ast.Name) and f.value.id == name):
                return True
            if _name_in_args(node, name):
                return True
    if isinstance(s, ast.Assign) and _contains_name(s.value, name):
        # a store into an attribute/subscript is a sink; an alias to
        # another local transfers the obligation (optimistic — flagging
        # aliases would make every hand-off pattern a false positive)
        return True
    if isinstance(s, ast.Return) and _contains_name(s.value, name):
        return True
    if (isinstance(s, ast.Expr)
            and isinstance(s.value, (ast.Yield, ast.YieldFrom))
            and _contains_name(s.value, name)):
        return True
    if isinstance(s, ast.Raise) and _contains_name(s, name):
        return True
    return False


def _bound_name(s: ast.stmt, allow_tuple: bool) -> Optional[str]:
    """The plain local this assignment binds, or the first element of a
    tuple target when ``allow_tuple`` (``ch, cid = expect_channel(...)``).
    Attribute/subscript targets are direct sinks, not acquisitions."""
    if isinstance(s, ast.Assign) and len(s.targets) == 1:
        t = s.targets[0]
    elif isinstance(s, ast.AnnAssign):
        t = s.target
    else:
        return None
    if isinstance(t, ast.Name):
        return t.id
    if (allow_tuple and isinstance(t, ast.Tuple) and t.elts
            and isinstance(t.elts[0], ast.Name)):
        return t.elts[0].id
    return None


def _call_value(s: ast.stmt) -> Optional[ast.Call]:
    v = s.value if isinstance(s, (ast.Assign, ast.AnnAssign)) else None
    return v if isinstance(v, ast.Call) else None


# -- DL601 ---------------------------------------------------------------------

def _future_acquisition(s: ast.stmt) -> Optional[str]:
    name = _bound_name(s, allow_tuple=False)
    call = _call_value(s)
    if name is None or call is None:
        return None
    f = call.func
    ctor = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else None)
    if ctor == "Future":
        return name
    if (isinstance(f, ast.Attribute) and f.attr in _DEQUEUE_METHODS
            and any(isinstance(n, (ast.Name, ast.Attribute))
                    and _FUTURE_CONTAINER.search(
                        n.id if isinstance(n, ast.Name) else n.attr)
                    for n in ast.walk(f.value))):
        return name
    return None


def _future_released(s: ast.stmt, name: str) -> bool:
    return _handed_off(s, name, _FUT_RESOLVE)


# -- DL602 ---------------------------------------------------------------------

def _resource_acquisition(s: ast.stmt) -> Optional[str]:
    name = _bound_name(s, allow_tuple=True)
    call = _call_value(s)
    if name is None or call is None:
        return None
    f = call.func
    fname = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else None)
    if fname in _RES_ACQ_FUNCS:
        return name
    if (isinstance(f, ast.Attribute) and f.attr == "socket"
            and isinstance(f.value, ast.Name) and f.value.id == "socket"):
        return name
    return None


def _resource_released(s: ast.stmt, name: str) -> bool:
    return _handed_off(s, name, _RES_RELEASE)


# -- the checker ---------------------------------------------------------------

@checker("flow", rules={
    "DL601": "future created/dequeued in runtime/ can complete a path "
             "unresolved (no set_result/set_exception, sink hand-off, or "
             "return on every normal exit)",
    "DL602": "channel/socket/SessionStore acquisition in runtime/ can exit "
             "(normally or by raising) without close()/hand-off to a "
             "shutdown owner",
})
def check(mods: List[ModuleInfo]) -> Iterable[Violation]:
    for mi in mods:
        if not mi.in_runtime:
            continue
        for qn, fn in iter_functions(mi.tree):
            cfg = CFG(fn)
            for s in list(cfg.stmt.values()):
                fut = _future_acquisition(s)
                if fut is not None \
                        and not RESOLVED_RE.search(mi.line(s.lineno)):
                    why = find_leak(cfg, s, fut, _future_released,
                                    raise_is_leak=False)
                    if why:
                        yield Violation(
                            "DL601", mi.relpath, s.lineno,
                            f"future {fut!r} in {qn} {why} without being "
                            "resolved, handed to a tracked sink, or "
                            "returned (suppress with '# deferlint: "
                            "resolved-by(<owner>)' if ownership is "
                            "transferred invisibly)",
                        )
                res = _resource_acquisition(s)
                if res is not None \
                        and not RESOLVED_RE.search(mi.line(s.lineno)):
                    why = find_leak(cfg, s, res, _resource_released,
                                    raise_is_leak=True)
                    if why:
                        yield Violation(
                            "DL602", mi.relpath, s.lineno,
                            f"resource {res!r} in {qn} {why} without "
                            "close()/hand-off to a shutdown owner "
                            "(suppress with '# deferlint: "
                            "resolved-by(<owner>)' if ownership is "
                            "transferred invisibly)",
                        )
