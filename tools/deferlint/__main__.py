import sys

from tools.deferlint.core import main

if __name__ == "__main__":
    sys.exit(main())
