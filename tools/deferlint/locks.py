"""DL201 — static lock-order analysis over runtime/.

Builds a lock-acquisition graph and fails on cycles.  Lock identities are
``(ClassName, attr)`` pairs discovered from ``self.X = threading.Lock() /
RLock() / Condition(...)`` assignments; ``Condition(self._lock)`` aliases
canonicalize to the underlying lock so ``with self._idle:`` and ``with
self._lock:`` are the same node.

Edges come from two sources:

1. ``with A: ... with B:`` nesting inside one function → edge A→B.
2. While A is held, a call to a method known (by name, within the linted
   file set) to acquire B → edge A→B, computed to a fixpoint over the
   "eventually acquires" relation so indirect chains are caught.

Name resolution is deliberately coarse — a call ``self.foo()`` or
``obj.foo()`` matches every method named ``foo`` in the linted set.  That
over-approximates edges, which is the right failure mode for a deadlock
lint: false cycles show up loudly at lint time and get refactored or
renamed, silent real cycles never ship.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.deferlint.core import ModuleInfo, Violation, checker, iter_functions

LOCK_CTORS = ("Lock", "RLock")
LockId = Tuple[str, str]  # (class qualname, attribute name)


def _self_attr(node: ast.AST) -> Optional[str]:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _ctor_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


class _ClassLocks:
    """Lock attributes of one class: real locks plus Condition aliases."""

    def __init__(self, cls: str):
        self.cls = cls
        self.locks: Set[str] = set()
        self.alias: Dict[str, str] = {}   # cond attr -> underlying lock attr

    def canon(self, attr: str) -> Optional[str]:
        if attr in self.locks:
            return attr
        return self.alias.get(attr)


def _discover_locks(mods: List[ModuleInfo]) -> Dict[str, _ClassLocks]:
    classes: Dict[str, _ClassLocks] = {}
    for mi in mods:
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            cl = classes.setdefault(node.name, _ClassLocks(node.name))
            for sub in ast.walk(node):
                if not (isinstance(sub, ast.Assign) and len(sub.targets) == 1):
                    continue
                attr = _self_attr(sub.targets[0])
                if attr is None or not isinstance(sub.value, ast.Call):
                    continue
                ctor = _ctor_name(sub.value)
                if ctor in LOCK_CTORS:
                    cl.locks.add(attr)
                elif ctor == "Condition":
                    if sub.value.args:
                        inner = _self_attr(sub.value.args[0])
                        if inner is not None:
                            cl.alias[attr] = inner
                            continue
                    # Condition() owns a private RLock: a lock in its own right
                    cl.locks.add(attr)
    return classes


def _method_class(fn_qualname: str) -> Optional[str]:
    # "Cls.method" or "Cls.method.<locals>.closure" -> "Cls"
    parts = fn_qualname.split(".")
    return parts[0] if len(parts) >= 2 else None


@checker("lock-discipline", rules={
    "DL201": "cycle in the static lock-acquisition graph across runtime/",
})
def check(mods: List[ModuleInfo]) -> Iterable[Violation]:
    rt = [m for m in mods if m.in_runtime]
    if not rt:
        return
    classes = _discover_locks(rt)

    # per-function: locks acquired directly, ordered edges from nesting,
    # and (held-lock, callee-name) pairs for the fixpoint.
    acquires: Dict[str, Set[LockId]] = {}
    edges: Set[Tuple[LockId, LockId]] = set()
    edge_site: Dict[Tuple[LockId, LockId], Tuple[str, int]] = {}
    held_calls: Dict[str, Set[Tuple[LockId, str, Tuple[str, int]]]] = {}
    methods_by_name: Dict[str, Set[str]] = {}

    for mi in rt:
        for qn, fn in iter_functions(mi.tree):
            cls = _method_class(qn)
            name = qn.split(".<locals>.")[-1].split(".")[-1]
            methods_by_name.setdefault(name, set()).add(qn)
            acquires.setdefault(qn, set())
            held_calls.setdefault(qn, set())
            _walk_fn(mi, qn, fn, cls, classes, acquires, edges, edge_site,
                     held_calls)

    # closures acquire on behalf of their enclosing method under the same
    # class; callee-name resolution: any method with that bare name.
    eventually: Dict[str, Set[LockId]] = {
        qn: set(a) for qn, a in acquires.items()
    }
    changed = True
    while changed:
        changed = False
        for qn, calls in held_calls.items():
            for _held, callee, _site in calls:
                for target in methods_by_name.get(callee, ()):
                    extra = eventually.get(target, set()) - eventually[qn]
                    if extra:
                        eventually[qn] |= extra
                        changed = True
        # also propagate plain (unheld) calls?  No: only held calls create
        # ordering edges; "eventually" only needs to cover what a callee
        # acquires so a held call can expand into edges below.

    for qn, calls in held_calls.items():
        for held, callee, site in calls:
            for target in methods_by_name.get(callee, ()):
                for acquired in eventually.get(target, ()):
                    if acquired != held:
                        e = (held, acquired)
                        if e not in edges:
                            edges.add(e)
                            edge_site[e] = site

    cycle = _find_cycle(edges)
    if cycle:
        desc = " -> ".join(f"{c}.{a}" for c, a in cycle)
        first = edge_site.get((cycle[0], cycle[1]),
                              (rt[0].relpath, 1)) if len(cycle) > 1 else (rt[0].relpath, 1)
        yield Violation(
            "DL201", first[0], first[1],
            f"lock-order cycle: {desc} (threads taking these locks in "
            "different orders can deadlock; break the cycle or refactor "
            "one side to drop the outer lock first)",
        )


def _walk_fn(mi, qn, fn, cls, classes, acquires, edges, edge_site, held_calls):
    """Single pass over one function body tracking the stack of held locks."""

    def resolve(expr: ast.AST) -> Optional[LockId]:
        attr = _self_attr(expr)
        if attr is None or cls is None:
            return None
        cl = classes.get(cls)
        if cl is None:
            return None
        canon = cl.canon(attr)
        return (cls, canon) if canon is not None else None

    def visit(node: ast.AST, held: Tuple[LockId, ...]):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return  # nested defs handled as their own functions
        if isinstance(node, ast.With):
            new_held = held
            for item in node.items:
                lock = resolve(item.context_expr)
                if lock is not None:
                    acquires[qn].add(lock)
                    for h in new_held:
                        if h != lock:
                            e = (h, lock)
                            if e not in edges:
                                edges.add(e)
                                edge_site[e] = (mi.relpath, node.lineno)
                    new_held = new_held + (lock,)
            for child in node.body:
                visit(child, new_held)
            return
        if isinstance(node, ast.Call) and held:
            f = node.func
            callee = None
            if isinstance(f, ast.Attribute):
                callee = f.attr
            elif isinstance(f, ast.Name):
                callee = f.id
            if callee and callee not in ("append", "pop", "get", "put",
                                         "add", "discard", "len", "items",
                                         "values", "keys", "notify",
                                         "notify_all", "wait", "format",
                                         "join", "set", "clear", "update",
                                         "copy", "extend", "remove"):
                for h in held:
                    held_calls[qn].add((h, callee, (mi.relpath, node.lineno)))
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for stmt in fn.body:
        visit(stmt, ())


def _find_cycle(edges: Set[Tuple[LockId, LockId]]) -> Optional[List[LockId]]:
    graph: Dict[LockId, Set[LockId]] = {}
    for a, b in edges:
        graph.setdefault(a, set()).add(b)
        graph.setdefault(b, set())
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in graph}
    stack: List[LockId] = []

    def dfs(n: LockId) -> Optional[List[LockId]]:
        color[n] = GREY
        stack.append(n)
        for m in sorted(graph[n]):
            if color[m] == GREY:
                i = stack.index(m)
                return stack[i:] + [m]
            if color[m] == WHITE:
                got = dfs(m)
                if got:
                    return got
        stack.pop()
        color[n] = BLACK
        return None

    for n in sorted(graph):
        if color[n] == WHITE:
            got = dfs(n)
            if got:
                return got
    return None
