"""DL401 — exception hygiene in runtime/ and the tools/benchmarks toolchain.

Every ``except Exception:`` (or broader: bare ``except:`` /
``except BaseException:``) must do one of:

* re-raise (``raise`` appears in the handler),
* resolve the failure into the runtime's error plumbing — call one of the
  known resolver functions (future completion, error-envelope
  construction, pending-failure fan-out), or reference
  ``traceback.format_exc`` (the error-envelope convention), or
* carry an explicit ``# deferlint: swallow(<reason>)`` tag on the
  ``except`` line.

The point is not to forbid swallowing — the runtime legitimately swallows
in best-effort teardown paths — but to make every swallow a reviewed,
greppable decision instead of an accident that turns a ``WireFormatError``
into a silent hang.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List

from tools.deferlint.core import (
    ModuleInfo, Violation, checker, enclosing_function_map,
)

SWALLOW_RE = re.compile(r"#\s*deferlint:\s*swallow\(([^)]+)\)")

# Calls that count as "resolved the failure into the error plumbing".
RESOLVERS = {
    "set_exception", "fail", "fail_extents", "fail_stranded",
    "on_member_death", "_fail_all_pending", "_finish_batch", "_unregister",
    "format_exc", "record_error",
}


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = set()
    for n in ast.walk(t):
        if isinstance(n, ast.Name):
            names.add(n.id)
        elif isinstance(n, ast.Attribute):
            names.add(n.attr)
    return bool(names.intersection({"Exception", "BaseException"}))


def _handler_ok(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else None)
            if name in RESOLVERS:
                return True
        if isinstance(node, ast.Attribute) and node.attr == "format_exc":
            return True
    return False


@checker("exception-hygiene", rules={
    "DL401": "except Exception that neither re-raises, resolves a "
             "future/error envelope, nor carries a swallow tag",
})
def check(mods: List[ModuleInfo]) -> Iterable[Violation]:
    for mi in mods:
        if not (mi.in_runtime or mi.in_toolchain):
            continue
        encl = enclosing_function_map(mi.tree)
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.ExceptHandler) or not _is_broad(node):
                continue
            if _handler_ok(node):
                continue
            if SWALLOW_RE.search(mi.line(node.lineno)):
                continue
            where = encl.get(node)
            qn = where[0] if where else "<module>"
            yield Violation(
                "DL401", mi.relpath, node.lineno,
                f"broad except in {qn} neither re-raises, resolves a "
                "future/error envelope, nor carries a "
                "'# deferlint: swallow(<reason>)' tag",
            )
