"""DL501 — token identity.

The runtime's stop/retire/close singletons (``_STOP``, ``_RETIRE``,
``_CLOSED``) are plain sentinel objects whose only meaningful comparison
is identity.  ``==`` happens to work today, but any payload type that
grows an ``__eq__`` (numpy arrays return elementwise arrays!) breaks a
``==`` comparison silently.  This rule flags any ``==`` / ``!=`` whose
left or right operand is one of the singleton names — use ``is`` /
``is not``.

Matching is by exact identifier name (bare or attribute), so integer wire
tags like ``_F_STOP`` compared with ``==`` are untouched.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from tools.deferlint.core import ModuleInfo, Violation, checker

SINGLETONS = {"_STOP", "_RETIRE", "_CLOSED"}


def _token_name(expr: ast.AST):
    if isinstance(expr, ast.Name) and expr.id in SINGLETONS:
        return expr.id
    if isinstance(expr, ast.Attribute) and expr.attr in SINGLETONS:
        return expr.attr
    return None


@checker("token-identity", rules={
    "DL501": "stop/fence singleton compared with ==/!= instead of "
             "is/is not",
})
def check(mods: List[ModuleInfo]) -> Iterable[Violation]:
    for mi in mods:
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Compare):
                continue
            operands = [node.left] + list(node.comparators)
            for op, left, right in zip(node.ops, operands, operands[1:]):
                if not isinstance(op, (ast.Eq, ast.NotEq)):
                    continue
                tok = _token_name(left) or _token_name(right)
                if tok is not None:
                    yield Violation(
                        "DL501", mi.relpath, node.lineno,
                        f"{tok} compared with "
                        f"{'==' if isinstance(op, ast.Eq) else '!='}; "
                        "sentinel singletons must use 'is' / 'is not'",
                    )
