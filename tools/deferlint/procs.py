"""DL304 — child-process discipline in runtime/.

Every child process created in runtime/ (``subprocess.Popen(...)``,
``multiprocessing.Process(...)``) must be reaped on some shutdown path:
the handle it is assigned to must have ``.wait()``, ``.terminate()``, or
``.kill()`` called on it somewhere in the linted set.  An unreaped child
is worse than an unjoined thread — it survives the interpreter, eating a
CPU (or holding sockets) until the machine is recycled, and its zombie
entry pins the process table.

Like DL301, the reap check is a *global* pass: the process may be spawned
in one function (the supervisor's ``_spawn``) and reaped in another
(``reap``/``close``) or even another module; what matters is that the
assigned handle name is reaped somewhere.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from tools.deferlint.core import ModuleInfo, Violation, checker, iter_functions

REAP_METHODS = ("wait", "terminate", "kill")


def _is_proc_ctor(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute):
        # subprocess.Popen(...) / multiprocessing.Process(...) /
        # mp.Process(...) — module alias doesn't matter, the attr does
        return f.attr in ("Popen", "Process")
    if isinstance(f, ast.Name):
        return f.id in ("Popen", "Process")
    return False


def _assigned_attr(fn: ast.AST, call: ast.Call) -> Optional[str]:
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and node.value is call:
            t = node.targets[0]
            if isinstance(t, ast.Attribute):
                return t.attr
            if isinstance(t, ast.Name):
                return t.id
    return None


@checker("process-discipline", rules={
    "DL304": "subprocess/multiprocessing child never reaped (no "
             "wait/terminate/kill on any shutdown path)",
})
def check(mods: List[ModuleInfo]) -> Iterable[Violation]:
    rt = [m for m in mods if m.in_runtime]
    if not rt:
        return

    # global view: which handle names ever get wait()/terminate()/kill()?
    reaped: Set[str] = set()
    for mi in rt:
        for node in ast.walk(mi.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in REAP_METHODS):
                tgt = node.func.value
                if isinstance(tgt, ast.Attribute):
                    reaped.add(tgt.attr)
                elif isinstance(tgt, ast.Name):
                    reaped.add(tgt.id)

    for mi in rt:
        for qn, fn in iter_functions(mi.tree):
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Call)
                        and _is_proc_ctor(node)):
                    continue
                target = _assigned_attr(fn, node)
                if target is not None and target in reaped:
                    continue
                yield Violation(
                    "DL304", mi.relpath, node.lineno,
                    f"child process created in {qn} is never reaped — no "
                    ".wait()/.terminate()/.kill() on its handle anywhere "
                    "in runtime/ (orphan survives the interpreter)",
                )
