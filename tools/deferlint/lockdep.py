"""Runtime lockdep: observe real lock-acquisition order during tests.

The static DL201 pass reasons about ``with self._lock:`` nesting it can
see; this shim catches what it can't — orders established through
callbacks, closures, and cross-module call chains.  Modeled on the Linux
kernel's lockdep: locks are grouped into *classes* keyed by their
creation site (file:line), and every observed "class A held while
acquiring class B" pair becomes an edge in a global order graph.  If both
A→B and B→A are ever observed — even on different threads, even minutes
apart — that's a latent deadlock, reported at session teardown.

Enable with ``DEFERLINT_LOCKDEP=1`` before importing the runtime (the
test conftest does this).  Only locks created from files under
``repro/runtime`` are instrumented; stdlib-internal locks (Condition's
private RLock, Thread._tstate_lock, ...) pass through untouched.
"""

from __future__ import annotations

import os
import sys
import threading
from typing import Dict, List, Optional, Set, Tuple

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock

ENV_FLAG = "DEFERLINT_LOCKDEP"


def _creation_site() -> Optional[Tuple[str, int]]:
    """First frame outside threading.py / this module — the real creator."""
    f = sys._getframe(2)
    skip = (os.sep + "threading.py", "lockdep.py")
    while f is not None:
        fn = f.f_code.co_filename
        if not fn.endswith(skip[0]) and not fn.endswith(skip[1]):
            return (fn, f.f_lineno)
        f = f.f_back
    return None


def _is_runtime_site(site: Optional[Tuple[str, int]]) -> bool:
    if site is None:
        return False
    path = site[0].replace(os.sep, "/")
    return "repro/runtime/" in path


class Registry:
    """Order graph over lock classes, plus per-thread held stacks."""

    def __init__(self) -> None:
        self._meta = _REAL_LOCK()
        self._edges: Dict[Tuple[str, str], Tuple[str, str]] = {}
        self._inversions: List[str] = []
        self._tls = threading.local()

    def _held(self) -> List[str]:
        h = getattr(self._tls, "held", None)
        if h is None:
            h = self._tls.held = []
        return h

    def note_acquire(self, cls: str, where: str) -> None:
        held = self._held()
        if held:
            with self._meta:
                for h in held:
                    if h == cls:
                        continue
                    fwd = (h, cls)
                    rev = (cls, h)
                    if rev in self._edges and fwd not in self._edges:
                        first = self._edges[rev]
                        self._inversions.append(
                            f"lock inversion: {h} -> {cls} at {where} "
                            f"conflicts with {cls} -> {h} first seen at "
                            f"{first[1]}"
                        )
                    self._edges.setdefault(fwd, (h, where))
        held.append(cls)

    def note_release(self, cls: str) -> None:
        held = self._held()
        # release order need not be LIFO (rare but legal); remove last match
        for i in range(len(held) - 1, -1, -1):
            if held[i] == cls:
                del held[i]
                return

    def inversions(self) -> List[str]:
        with self._meta:
            return list(self._inversions)

    def reset(self) -> None:
        with self._meta:
            self._edges.clear()
            self._inversions.clear()


_registry = Registry()


def registry() -> Registry:
    return _registry


class _InstrumentedLock:
    """Wraps a real lock, reporting acquire/release order to a Registry.

    Implements the private Condition protocol (_release_save /
    _acquire_restore / _is_owned) by delegating, so instrumented locks can
    back ``threading.Condition`` transparently.
    """

    def __init__(self, inner, cls: str, reg: Registry):
        self._inner = inner
        self._cls = cls
        self._reg = reg

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            where = self._caller()
            self._reg.note_acquire(self._cls, where)
        return got

    def release(self) -> None:
        self._inner.release()
        self._reg.note_release(self._cls)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    # Condition protocol -------------------------------------------------
    def _release_save(self):
        self._reg.note_release(self._cls)
        return self._inner._release_save() if hasattr(
            self._inner, "_release_save") else (self._inner.release() or None)

    def _acquire_restore(self, state) -> None:
        if hasattr(self._inner, "_acquire_restore"):
            self._inner._acquire_restore(state)
        else:
            self._inner.acquire()
        self._reg.note_acquire(self._cls, "<cond-reacquire>")

    def _is_owned(self) -> bool:
        if hasattr(self._inner, "_is_owned"):
            return self._inner._is_owned()
        if self._inner.acquire(False):
            self._inner.release()
            return False
        return True

    @staticmethod
    def _caller() -> str:
        f = sys._getframe(2)
        while f is not None and (
                f.f_code.co_filename.endswith("lockdep.py")
                or f.f_code.co_filename.endswith(os.sep + "threading.py")):
            f = f.f_back
        if f is None:
            return "<unknown>"
        return f"{f.f_code.co_filename}:{f.f_lineno}"


def _make_factory(real_ctor, kind: str, reg: Registry):
    def factory():
        inner = real_ctor()
        site = _creation_site()
        if not _is_runtime_site(site):
            return inner
        path = site[0].replace(os.sep, "/")
        short = "/".join(path.split("/")[-2:])
        cls = f"{kind}@{short}:{site[1]}"
        return _InstrumentedLock(inner, cls, reg)
    return factory


_installed = False


def install(reg: Optional[Registry] = None) -> None:
    """Monkeypatch threading.Lock/RLock with instrumented factories."""
    global _installed
    if _installed:
        return
    reg = reg or _registry
    threading.Lock = _make_factory(_REAL_LOCK, "Lock", reg)
    threading.RLock = _make_factory(_REAL_RLOCK, "RLock", reg)
    _installed = True


def install_if_enabled() -> bool:
    if os.environ.get(ENV_FLAG) == "1":
        install()
        return True
    return False


def running_nondaemon_threads(before: Set[threading.Thread]) -> List[threading.Thread]:
    """Threads alive now that are non-daemon, not main, and not in `before`."""
    out = []
    for t in threading.enumerate():
        if t in before or t.daemon or t is threading.main_thread():
            continue
        if t.is_alive():
            out.append(t)
    return out
