"""DL301/DL302/DL303 — thread discipline in runtime/.

DL301: every ``threading.Thread(...)`` must either pass ``daemon=True`` at
construction (or set ``.daemon = True`` before ``.start()`` in the same
function) or be joined somewhere in the linted set — otherwise shutdown
can hang forever on a forgotten worker.

DL302: a ``while True:`` loop whose body blocks on a bare ``.get()`` /
``.recv()`` must have a stop path: the loop (or its enclosing function)
must reference one of the stop/close singletons (``_STOP``, ``_RETIRE``,
``_CLOSED``) or handle ``ChannelClosed`` — the runtime's convention for
"this loop is told to die, it doesn't need to be killed".  Unbounded
``.join()`` calls are only allowed inside shutdown-path functions
(``stop``/``join``/``shutdown``/``drain``/``close``/``scale``/``retire``)
or with an explicit timeout.

DL303: ``time.sleep`` anywhere except ``LinkChannel`` (the emulated-link
rate shaper, the one place wall-clock pacing is the point) — everywhere
else, sleeping is a latent flake or a poll loop that should be a
condition wait.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set, Tuple

from tools.deferlint.core import ModuleInfo, Violation, checker, iter_functions

STOP_TOKENS = ("_STOP", "_RETIRE", "_CLOSED")
SHUTDOWN_FN_NAMES = ("stop", "join", "shutdown", "drain", "close", "scale",
                     "retire", "__exit__", "broadcast")


def _enclosing_class(qn: str) -> Optional[str]:
    parts = qn.split(".")
    return parts[0] if len(parts) >= 2 else None


@checker("thread-discipline", rules={
    "DL301": "threading.Thread neither daemon=True nor joined in a "
             "shutdown path",
    "DL302": "blocking get()/recv() loop with no stop-token path, or "
             "unbounded join outside shutdown",
    "DL303": "time.sleep outside the LinkChannel rate shaper",
})
def check(mods: List[ModuleInfo]) -> Iterable[Violation]:
    rt = [m for m in mods if m.in_runtime]
    if not rt:
        return

    # global view: which thread-target names are ever joined?
    joined_attrs: Set[str] = set()
    for mi in rt:
        for node in ast.walk(mi.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "join"):
                tgt = node.func.value
                if isinstance(tgt, ast.Attribute):
                    joined_attrs.add(tgt.attr)
                elif isinstance(tgt, ast.Name):
                    joined_attrs.add(tgt.id)

    for mi in rt:
        yield from _check_module(mi, joined_attrs)


def _is_thread_ctor(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr == "Thread":
        return isinstance(f.value, ast.Name) and f.value.id == "threading"
    if isinstance(f, ast.Name) and f.id == "Thread":
        return True
    return False


def _check_module(mi: ModuleInfo, joined_attrs: Set[str]) -> Iterable[Violation]:
    for qn, fn in iter_functions(mi.tree):
        fname = qn.split(".<locals>.")[-1].split(".")[-1]
        cls = _enclosing_class(qn)
        fn_src_names = {n.id for n in ast.walk(fn) if isinstance(n, ast.Name)}
        fn_attr_names = {n.attr for n in ast.walk(fn)
                         if isinstance(n, ast.Attribute)}

        # DL301 — Thread construction
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and _is_thread_ctor(node):
                daemon = any(
                    kw.arg == "daemon"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                    for kw in node.keywords
                )
                if daemon:
                    continue
                # assigned to self.X or local later joined?
                target_attr = _assigned_attr(fn, node)
                if target_attr is not None and target_attr in joined_attrs:
                    continue
                if _daemon_set_after(fn, node, target_attr):
                    continue
                yield Violation(
                    "DL301", mi.relpath, node.lineno,
                    f"Thread created in {qn} is neither daemon=True nor "
                    "joined anywhere in runtime/ (shutdown can hang on it)",
                )

        # DL302 — blocking loops and unbounded joins
        handles_closed = _handles_channel_closed(fn)
        has_stop_ref = bool(fn_src_names.intersection(STOP_TOKENS)
                            or fn_attr_names.intersection(STOP_TOKENS))
        for node in ast.walk(fn):
            if isinstance(node, ast.While) and _is_while_true(node):
                blocking = _blocking_get_lines(node)
                if blocking and not (has_stop_ref or handles_closed):
                    yield Violation(
                        "DL302", mi.relpath, blocking[0],
                        f"while-True loop in {qn} blocks on .get()/.recv() "
                        "with no stop-token reference or ChannelClosed "
                        "handler — unkillable without daemon teardown",
                    )
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "join"
                    and not node.args and not node.keywords):
                base = node.func.value
                # str.join(iterable) always has an argument; argless .join()
                # here is a thread/queue join.
                if fname not in SHUTDOWN_FN_NAMES:
                    yield Violation(
                        "DL302", mi.relpath, node.lineno,
                        f"unbounded .join() in {qn} (only shutdown-path "
                        "functions may block forever; pass a timeout)",
                    )
                del base

        # DL303 — time.sleep outside the shaper
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "sleep"
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "time"
                    and cls != "LinkChannel"):
                yield Violation(
                    "DL303", mi.relpath, node.lineno,
                    f"time.sleep in {qn}: wall-clock pacing belongs only in "
                    "LinkChannel's shaper; use condition waits elsewhere",
                )


def _assigned_attr(fn: ast.AST, call: ast.Call) -> Optional[str]:
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and node.value is call:
            t = node.targets[0]
            if isinstance(t, ast.Attribute):
                return t.attr
            if isinstance(t, ast.Name):
                return t.id
    return None


def _daemon_set_after(fn: ast.AST, call: ast.Call,
                      target: Optional[str]) -> bool:
    if target is None:
        return False
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign)
                and node.lineno > call.lineno
                and isinstance(node.targets[0], ast.Attribute)
                and node.targets[0].attr == "daemon"
                and isinstance(node.value, ast.Constant)
                and node.value.value is True):
            base = node.targets[0].value
            name = base.attr if isinstance(base, ast.Attribute) else (
                base.id if isinstance(base, ast.Name) else None)
            if name == target:
                return True
    return False


def _is_while_true(node: ast.While) -> bool:
    return isinstance(node.test, ast.Constant) and node.test.value is True


def _blocking_get_lines(loop: ast.While) -> List[int]:
    out: List[int] = []
    for node in ast.walk(loop):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("get", "recv")
                and not node.args and not node.keywords):
            out.append(node.lineno)
    return sorted(out)


def _handles_channel_closed(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.ExceptHandler) and node.type is not None:
            names = {n.id for n in ast.walk(node.type)
                     if isinstance(n, ast.Name)}
            attrs = {n.attr for n in ast.walk(node.type)
                     if isinstance(n, ast.Attribute)}
            if "ChannelClosed" in names or "ChannelClosed" in attrs:
                return True
    return False
