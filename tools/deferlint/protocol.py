"""DL603/DL604 — wire-protocol drift.

DL603 (wire-tag exhaustiveness): the tag universes are harvested from
``runtime/wire.py`` — module-level ``_F_*`` frame types, ``K_*`` extent
kinds, and the ``_COMPAT_VERSIONS`` tuple.  Every *dispatch chain* over
one of those universes anywhere in ``runtime/`` must handle all members
or end in a catch-all else that raises / relays a ``WireFormatError``
(or builds an error envelope).  A dispatch chain is either an
``if/elif`` ladder with >= 2 arms testing the same subject against
universe members, or a run of >= 2 consecutive sibling ``if`` statements
with terminal bodies (return/raise/continue/break) doing the same.
Single scattered membership tests are not chains — routing code that
peels one kind off and forwards the rest is fine.  The point: the next
wire bump cannot silently skip ``node.py`` or ``unframe_compat``.

DL604 (control-protocol drift): the set of ``ControlFrame`` verbs
``supervisor.py`` sends must equal the set ``worker.py``'s control loop
handles, and vice versa (acks/heartbeats flow worker -> supervisor).  A
verb sent but never handled is a silent no-op; a verb handled but never
sent is a dead arm that rots.  Suppress a deliberate asymmetry with
``# deferlint: control-verb(<reason>)`` on the anchor line.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from tools.deferlint.core import (
    ModuleInfo, Violation, checker, enclosing_function_map,
)
from tools.deferlint.flow import RESOLVED_RE

CONTROL_RE = re.compile(r"#\s*deferlint:\s*control-verb\(([^)]+)\)")

_FRAME_RE = re.compile(r"_F_[A-Z_]+\Z")
_KIND_RE = re.compile(r"K_[A-Z_]+\Z")
_VERSIONISH = re.compile(r"version", re.IGNORECASE)


# -- universe harvest ----------------------------------------------------------

def _harvest_universes(mods: List[ModuleInfo]) -> Dict[str, Set[str]]:
    """Tag universes from modules named ``wire.py``: member *names* for
    the frame/kind universes, stringified ints for the version universe
    (``_COMPAT_VERSIONS`` with ``FRAME_VERSION`` references resolved)."""
    frame: Set[str] = set()
    kind: Set[str] = set()
    consts: Dict[str, int] = {}
    compat_elts: List[ast.expr] = []
    for mi in mods:
        if not mi.in_runtime or os.path.basename(mi.relpath) != "wire.py":
            continue
        for node in mi.tree.body:
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            name = node.targets[0].id
            v = node.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                consts[name] = v.value
                if _FRAME_RE.match(name):
                    frame.add(name)
                elif _KIND_RE.match(name):
                    kind.add(name)
            elif name == "_COMPAT_VERSIONS" and isinstance(v, ast.Tuple):
                compat_elts = list(v.elts)
    version: Set[str] = set()
    for e in compat_elts:
        if isinstance(e, ast.Constant) and isinstance(e.value, int):
            version.add(str(e.value))
        elif isinstance(e, ast.Name) and e.id in consts:
            version.add(str(consts[e.id]))
    return {"frame": frame, "kind": kind, "version": version}


# -- dispatch-chain detection --------------------------------------------------

def _member(e: ast.expr) -> Optional[str]:
    if isinstance(e, ast.Name):
        return e.id
    if isinstance(e, ast.Attribute):
        return e.attr
    return None


def _versionish(subject: ast.expr) -> bool:
    for n in ast.walk(subject):
        if isinstance(n, ast.Name) and _VERSIONISH.search(n.id):
            return True
        if isinstance(n, ast.Attribute) and _VERSIONISH.search(n.attr):
            return True
    return False


def _match_test(test: ast.expr, universes: Dict[str, Set[str]]):
    """Classify one branch test as a universe-membership check.  Returns
    ``(subject_key, members, universe_name)`` or None.  Version members
    are bare int literals, so they only count when the subject is
    literally named like a version — anything looser would flag every
    small-int ladder in the repo."""
    if not (isinstance(test, ast.Compare) and len(test.ops) == 1):
        return None
    op = test.ops[0]
    left, right = test.left, test.comparators[0]
    if isinstance(op, ast.Eq):
        for subj, memb in ((left, right), (right, left)):
            m = _member(memb)
            for uni in ("frame", "kind"):
                if m is not None and m in universes[uni]:
                    return ast.dump(subj), frozenset([m]), uni
            if (isinstance(memb, ast.Constant)
                    and isinstance(memb.value, int)
                    and str(memb.value) in universes["version"]
                    and _versionish(subj)):
                return ast.dump(subj), frozenset([str(memb.value)]), "version"
    elif isinstance(op, ast.In) and isinstance(right,
                                               (ast.Tuple, ast.List, ast.Set)):
        members = [_member(e) for e in right.elts]
        for uni in ("frame", "kind"):
            if members and all(m is not None and m in universes[uni]
                               for m in members):
                return ast.dump(left), frozenset(members), uni
        if (right.elts and _versionish(left)
                and all(isinstance(e, ast.Constant)
                        and isinstance(e.value, int)
                        and str(e.value) in universes["version"]
                        for e in right.elts)):
            return (ast.dump(left),
                    frozenset(str(e.value) for e in right.elts), "version")
    return None


def _is_catchall(stmts: Sequence[ast.stmt]) -> bool:
    """Does this else body relay the unknown tag — raise, build an
    ``error=...`` envelope, or assign into an ``*error*`` name?"""
    for s in stmts:
        for node in ast.walk(s):
            if isinstance(node, ast.Raise):
                return True
            if isinstance(node, ast.Call) and any(
                    kw.arg == "error" for kw in node.keywords):
                return True
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and "error" in t.id.lower()
                    for t in node.targets):
                return True
    return False


def _ladder(head: ast.If) -> Tuple[List[ast.If], List[ast.stmt]]:
    """Follow the elif chain from ``head``; returns (branch Ifs, final
    else body)."""
    branches = [head]
    cur = head
    while len(cur.orelse) == 1 and isinstance(cur.orelse[0], ast.If):
        cur = cur.orelse[0]
        branches.append(cur)
    return branches, cur.orelse


def _terminal(body: Sequence[ast.stmt]) -> bool:
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


def _iter_blocks(tree: ast.AST):
    for node in ast.walk(tree):
        for field in ("body", "orelse", "finalbody"):
            blk = getattr(node, field, None)
            if (isinstance(blk, list) and blk
                    and all(isinstance(x, ast.stmt) for x in blk)):
                yield blk


def _check_chain(mi: ModuleInfo, encl, head_line: int,
                 covered: Set[str], universe: Set[str], uni_name: str,
                 has_catchall: bool) -> Iterable[Violation]:
    if has_catchall or covered >= universe:
        return
    if RESOLVED_RE.search(mi.line(head_line)):
        return
    missing = ", ".join(sorted(universe - covered))
    where = encl.get_line(head_line)
    yield Violation(
        "DL603", mi.relpath, head_line,
        f"dispatch over the {uni_name} tag universe in {where} handles "
        f"{{{', '.join(sorted(covered))}}} but not {{{missing}}} and has "
        "no catch-all else that raises/relays WireFormatError",
    )


class _Encl:
    """Line -> enclosing-function-qualname lookup for messages."""

    def __init__(self, tree: ast.AST):
        self._map = enclosing_function_map(tree)
        self._by_line: Dict[int, str] = {}
        for node, (qn, _fn) in self._map.items():
            ln = getattr(node, "lineno", None)
            if ln is not None and ln not in self._by_line:
                self._by_line[ln] = qn

    def get_line(self, line: int) -> str:
        return self._by_line.get(line, "<module>")


def _check_dispatches(mi: ModuleInfo,
                      universes: Dict[str, Set[str]]) -> Iterable[Violation]:
    encl = _Encl(mi.tree)
    consumed: Set[int] = set()   # id(If) already folded into a ladder

    # pass 1: if/elif ladders (ast.walk yields parents before their elifs,
    # so marking elif arms consumed prevents re-checking ladder suffixes)
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.If) or id(node) in consumed:
            continue
        branches, else_body = _ladder(node)
        for b in branches[1:]:
            consumed.add(id(b))
        groups: Dict[Tuple[str, str], Set[str]] = {}
        first_line: Dict[Tuple[str, str], int] = {}
        arm_count: Dict[Tuple[str, str], int] = {}
        for b in branches:
            m = _match_test(b.test, universes)
            if m is None:
                continue
            subj, members, uni = m
            groups.setdefault((subj, uni), set()).update(members)
            first_line.setdefault((subj, uni), b.lineno)
            arm_count[(subj, uni)] = arm_count.get((subj, uni), 0) + 1
        for (subj, uni), covered in groups.items():
            if arm_count[(subj, uni)] < 2:
                continue
            yield from _check_chain(mi, encl, first_line[(subj, uni)],
                                    covered, universes[uni], uni,
                                    _is_catchall(else_body))

    # pass 2: sibling runs — consecutive `if <subject> == MEMBER: ...` with
    # terminal bodies, the `_unframe_versions` style; a trailing raise
    # right after the run is its catch-all
    for blk in _iter_blocks(mi.tree):
        i = 0
        while i < len(blk):
            s = blk[i]
            m = (_match_test(s.test, universes)
                 if isinstance(s, ast.If) and not s.orelse
                 and _terminal(s.body) else None)
            if m is None:
                i += 1
                continue
            subj, members, uni = m
            covered = set(members)
            head_line = s.lineno
            j = i + 1
            while j < len(blk):
                nxt = blk[j]
                nm = (_match_test(nxt.test, universes)
                      if isinstance(nxt, ast.If) and not nxt.orelse
                      and _terminal(nxt.body) else None)
                if nm is None or nm[0] != subj or nm[2] != uni:
                    break
                covered.update(nm[1])
                j += 1
            run_len = j - i
            if run_len >= 2:
                trailing_raise = j < len(blk) and isinstance(blk[j], ast.Raise)
                yield from _check_chain(mi, encl, head_line, covered,
                                        universes[uni], uni, trailing_raise)
            i = j
    return


@checker("wire-exhaustiveness", rules={
    "DL603": "dispatch chain over a wire.py tag universe (_F_* / K_* / "
             "_COMPAT_VERSIONS) missing members and lacking a catch-all "
             "else that raises/relays WireFormatError",
})
def check_dispatch(mods: List[ModuleInfo]) -> Iterable[Violation]:
    universes = _harvest_universes(mods)
    if not any(universes.values()):
        return
    for mi in mods:
        if not mi.in_runtime:
            continue
        yield from _check_dispatches(mi, universes)


# -- DL604: supervisor <-> worker verb drift -----------------------------------

def _control_sends(mi: ModuleInfo) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else None)
        if (name == "ControlFrame" and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            out.setdefault(node.args[0].value, node.lineno)
    return out


def _control_handles(mi: ModuleInfo) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for node in ast.walk(mi.tree):
        if not (isinstance(node, ast.Compare) and len(node.ops) == 1):
            continue
        op = node.ops[0]
        left, right = node.left, node.comparators[0]
        if isinstance(op, ast.Eq):
            for a, b in ((left, right), (right, left)):
                if (isinstance(a, ast.Attribute) and a.attr == "kind"
                        and isinstance(b, ast.Constant)
                        and isinstance(b.value, str)):
                    out.setdefault(b.value, node.lineno)
        elif (isinstance(op, ast.In) and isinstance(left, ast.Attribute)
                and left.attr == "kind"
                and isinstance(right, (ast.Tuple, ast.List, ast.Set))):
            for e in right.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    out.setdefault(e.value, node.lineno)
    return out


@checker("control-protocol", rules={
    "DL604": "ControlFrame verb drift between supervisor.py and worker.py "
             "(verb sent but never handled, or handled but never sent)",
})
def check_control(mods: List[ModuleInfo]) -> Iterable[Violation]:
    sup = wrk = None
    for mi in mods:
        rel = "/" + mi.relpath.replace(os.sep, "/")
        if rel.endswith("/runtime/supervisor.py"):
            sup = sup or mi
        elif rel.endswith("/runtime/worker.py"):
            wrk = wrk or mi
    if sup is None or wrk is None:
        return
    for sender, s_role, handler, h_role in ((sup, "supervisor", wrk, "worker"),
                                            (wrk, "worker", sup,
                                             "supervisor")):
        sends = _control_sends(sender)
        handles = _control_handles(handler)
        for verb, line in sorted(sends.items()):
            if verb in handles or CONTROL_RE.search(sender.line(line)):
                continue
            yield Violation(
                "DL604", sender.relpath, line,
                f"{s_role} sends ControlFrame({verb!r}) but the {h_role} "
                "control loop never handles it (suppress a deliberate "
                "asymmetry with '# deferlint: control-verb(<reason>)')",
            )
        for verb, line in sorted(handles.items()):
            if verb in sends or CONTROL_RE.search(handler.line(line)):
                continue
            yield Violation(
                "DL604", handler.relpath, line,
                f"{h_role} handles ControlFrame kind {verb!r} that the "
                f"{s_role} never sends — dead arm or missing sender "
                "(suppress with '# deferlint: control-verb(<reason>)')",
            )
