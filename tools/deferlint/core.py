"""deferlint core: module loading, checker registry, reporting.

deferlint is a purpose-built static analyzer for this repo's runtime.  It
does not try to be a general linter: every rule encodes one invariant the
distributed runtime actually depends on (bounds-checked wire reads,
identity-compared stop tokens, acyclic lock order, auditable exception
swallowing, joinable threads).  Rules are small AST passes registered via
``@checker``; ``lint_paths`` walks the target tree once, parses each module,
and hands the parsed ``ModuleInfo`` set to every checker.

Suppression mechanisms (use sparingly, the bar is "a reviewer agreed the
invariant genuinely does not apply here"):

* ``# deferlint: swallow(<reason>)`` on the ``except`` line — DL401 only.
* An ``ALLOWLIST`` entry keyed by (path suffix, qualname) — DL101 only,
  reserved for codec internals whose callers already wrap decode errors.
"""

from __future__ import annotations

import ast
import os
import sys
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Violation:
    rule: str          # e.g. "DL101"
    path: str          # repo-relative path
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclass
class ModuleInfo:
    path: str                 # absolute path
    relpath: str              # path relative to the lint root's parent (posix)
    tree: ast.Module
    source_lines: List[str] = field(default_factory=list)

    @property
    def in_runtime(self) -> bool:
        return "/runtime/" in "/" + self.relpath.replace(os.sep, "/")

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.source_lines):
            return self.source_lines[lineno - 1]
        return ""


CheckerFn = Callable[[List[ModuleInfo]], Iterable[Violation]]
_CHECKERS: List[Tuple[str, CheckerFn]] = []


def checker(name: str) -> Callable[[CheckerFn], CheckerFn]:
    def wrap(fn: CheckerFn) -> CheckerFn:
        _CHECKERS.append((name, fn))
        return fn
    return wrap


def iter_functions(tree: ast.AST):
    """Yield (qualname, funcdef) for every function/method, including
    nested closures (qualified as ``outer.<locals>.inner``)."""

    def visit(node: ast.AST, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}{child.name}"
                yield qn, child
                yield from visit(child, f"{qn}.<locals>.")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)

    yield from visit(tree, "")


def enclosing_function_map(tree: ast.AST) -> Dict[ast.AST, Tuple[str, ast.AST]]:
    """Map every AST node to its innermost enclosing (qualname, funcdef)."""
    out: Dict[ast.AST, Tuple[str, ast.AST]] = {}
    for qn, fn in iter_functions(tree):
        for node in ast.walk(fn):
            if node is fn:
                continue
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            # innermost wins: iter_functions yields outer before inner, so
            # later (inner) assignments overwrite earlier (outer) ones.
            out[node] = (qn, fn)
    # nodes inside nested functions got overwritten correctly because inner
    # functions are yielded after their enclosing function and re-walk the
    # same subtree.
    return out


def load_module(path: str, root_parent: str) -> Optional[ModuleInfo]:
    try:
        with open(path, "r", encoding="utf-8") as f:
            src = f.read()
        tree = ast.parse(src, filename=path)
    except (OSError, SyntaxError) as e:
        print(f"deferlint: cannot parse {path}: {e}", file=sys.stderr)
        return None
    rel = os.path.relpath(path, root_parent).replace(os.sep, "/")
    return ModuleInfo(path=path, relpath=rel, tree=tree,
                      source_lines=src.splitlines())


def collect_modules(paths: Sequence[str]) -> List[ModuleInfo]:
    mods: List[ModuleInfo] = []
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isfile(p):
            mi = load_module(p, os.path.dirname(p))
            if mi:
                mods.append(mi)
            continue
        root_parent = os.path.dirname(p.rstrip(os.sep))
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    mi = load_module(os.path.join(dirpath, fn), root_parent)
                    if mi:
                        mods.append(mi)
    return mods


def lint_paths(paths: Sequence[str]) -> List[Violation]:
    mods = collect_modules(paths)
    # checker modules register themselves on import
    from tools.deferlint import (  # noqa: F401
        hygiene, locks, procs, threads, tokens, wire_safety,
    )
    out: List[Violation] = []
    for _name, fn in _CHECKERS:
        out.extend(fn(mods))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


RULE_CATALOG = {
    "DL101": "struct.unpack/unpack_from not behind wire._checked (allowlist: core/codecs.py internals only)",
    "DL102": "pickle/marshal import or eval/exec call inside runtime/",
    "DL103": "time.time() inside runtime/ (deadlines/backoff must use time.monotonic or perf_counter)",
    "DL201": "cycle in the static lock-acquisition graph across runtime/",
    "DL301": "threading.Thread neither daemon=True nor joined in a shutdown path",
    "DL302": "blocking get()/recv() loop with no stop-token path, or unbounded join outside shutdown",
    "DL303": "time.sleep outside the LinkChannel rate shaper",
    "DL304": "subprocess/multiprocessing child never reaped (no wait/terminate/kill on any shutdown path)",
    "DL401": "except Exception that neither re-raises, resolves a future/error envelope, nor carries a swallow tag",
    "DL501": "stop/fence singleton compared with ==/!= instead of is/is not",
}


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m tools.deferlint <path> [<path> ...]")
        print("\nrules:")
        for rid, desc in sorted(RULE_CATALOG.items()):
            print(f"  {rid}  {desc}")
        return 0 if argv else 2
    violations = lint_paths(argv)
    for v in violations:
        print(v.format())
    if violations:
        print(f"deferlint: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    print("deferlint: clean")
    return 0
