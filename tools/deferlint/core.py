"""deferlint core: module loading, checker registry, reporting.

deferlint is a purpose-built static analyzer for this repo's runtime.  It
does not try to be a general linter: every rule encodes one invariant the
distributed runtime actually depends on (bounds-checked wire reads,
identity-compared stop tokens, acyclic lock order, auditable exception
swallowing, joinable threads).  Rules are small AST passes registered via
``@checker``; ``lint_paths`` walks the target tree once, parses each module,
and hands the parsed ``ModuleInfo`` set to every checker.

Suppression mechanisms (use sparingly, the bar is "a reviewer agreed the
invariant genuinely does not apply here"):

* ``# deferlint: swallow(<reason>)`` on the ``except`` line — DL401 only.
* An ``ALLOWLIST`` entry keyed by (path suffix, qualname) — DL101 only,
  reserved for codec internals whose callers already wrap decode errors.
* ``# deferlint: resolved-by(<owner>)`` on an acquisition/dispatch line —
  the flow rules (DL601/DL602/DL603), for ownership transfers the CFG
  walk cannot see.
* ``# deferlint: control-verb(<reason>)`` — DL604, for a deliberate
  supervisor/worker verb asymmetry (e.g. a verb only a test harness
  sends).
"""

from __future__ import annotations

import ast
import json
import os
import sys
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Violation:
    rule: str          # e.g. "DL101"
    path: str          # repo-relative path
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclass
class ModuleInfo:
    path: str                 # absolute path
    relpath: str              # path relative to the lint root's parent (posix)
    tree: ast.Module
    source_lines: List[str] = field(default_factory=list)

    @property
    def in_runtime(self) -> bool:
        return "/runtime/" in "/" + self.relpath.replace(os.sep, "/")

    @property
    def in_toolchain(self) -> bool:
        """tools/ and benchmarks/ — self-linted with the hygiene rules
        (DL102/DL401/DL501) but exempt from runtime-only rules."""
        p = "/" + self.relpath.replace(os.sep, "/")
        return "/tools/" in p or "/benchmarks/" in p

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.source_lines):
            return self.source_lines[lineno - 1]
        return ""


CheckerFn = Callable[[List[ModuleInfo]], Iterable[Violation]]
_CHECKERS: List[Tuple[str, CheckerFn, Dict[str, str]]] = []

# rule id -> one-line description, assembled from the ``rules=`` each
# checker declares at registration (so --help can never drift from what
# is actually enforced).  Populated once the checker modules import.
RULE_CATALOG: Dict[str, str] = {}


def checker(name: str, rules: Optional[Dict[str, str]] = None,
            ) -> Callable[[CheckerFn], CheckerFn]:
    def wrap(fn: CheckerFn) -> CheckerFn:
        _CHECKERS.append((name, fn, dict(rules or {})))
        RULE_CATALOG.update(rules or {})
        return fn
    return wrap


def _load_checkers() -> None:
    """Checker modules register themselves (and their catalog rows) on
    import."""
    from tools.deferlint import (  # noqa: F401
        flow, hygiene, locks, procs, protocol, threads, tokens, wire_safety,
    )


def iter_functions(tree: ast.AST):
    """Yield (qualname, funcdef) for every function/method, including
    nested closures (qualified as ``outer.<locals>.inner``)."""

    def visit(node: ast.AST, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}{child.name}"
                yield qn, child
                yield from visit(child, f"{qn}.<locals>.")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)

    yield from visit(tree, "")


def enclosing_function_map(tree: ast.AST) -> Dict[ast.AST, Tuple[str, ast.AST]]:
    """Map every AST node to its innermost enclosing (qualname, funcdef)."""
    out: Dict[ast.AST, Tuple[str, ast.AST]] = {}
    for qn, fn in iter_functions(tree):
        for node in ast.walk(fn):
            if node is fn:
                continue
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            # innermost wins: iter_functions yields outer before inner, so
            # later (inner) assignments overwrite earlier (outer) ones.
            out[node] = (qn, fn)
    # nodes inside nested functions got overwritten correctly because inner
    # functions are yielded after their enclosing function and re-walk the
    # same subtree.
    return out


def load_module(path: str, root_parent: str) -> Optional[ModuleInfo]:
    try:
        with open(path, "r", encoding="utf-8") as f:
            src = f.read()
        tree = ast.parse(src, filename=path)
    except (OSError, SyntaxError) as e:
        print(f"deferlint: cannot parse {path}: {e}", file=sys.stderr)
        return None
    rel = os.path.relpath(path, root_parent).replace(os.sep, "/")
    return ModuleInfo(path=path, relpath=rel, tree=tree,
                      source_lines=src.splitlines())


def collect_modules(paths: Sequence[str]) -> List[ModuleInfo]:
    mods: List[ModuleInfo] = []
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isfile(p):
            mi = load_module(p, os.path.dirname(p))
            if mi:
                mods.append(mi)
            continue
        root_parent = os.path.dirname(p.rstrip(os.sep))
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    mi = load_module(os.path.join(dirpath, fn), root_parent)
                    if mi:
                        mods.append(mi)
    return mods


def lint_paths(paths: Sequence[str]) -> List[Violation]:
    mods = collect_modules(paths)
    _load_checkers()
    out: List[Violation] = []
    for _name, fn, _rules in _CHECKERS:
        out.extend(fn(mods))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def _usage(file=sys.stdout) -> None:
    print("usage: python -m tools.deferlint [--json] [--github] "
          "[--select DLxxx[,...]] [--ignore DLxxx[,...]] "
          "<path> [<path> ...]", file=file)


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        _load_checkers()
        _usage()
        print("\nrules:")
        for rid, desc in sorted(RULE_CATALOG.items()):
            print(f"  {rid}  {desc}")
        return 0 if argv else 2
    as_json = as_github = False
    select: set = set()
    ignore: set = set()
    paths: List[str] = []
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--json":
            as_json = True
        elif a == "--github":
            as_github = True
        elif a in ("--select", "--ignore") or a.startswith(("--select=",
                                                           "--ignore=")):
            if "=" in a:
                opt, _, val = a.partition("=")
            else:
                opt = a
                i += 1
                if i >= len(argv):
                    print(f"deferlint: {opt} needs an argument",
                          file=sys.stderr)
                    return 2
                val = argv[i]
            rids = {r.strip().upper() for r in val.split(",") if r.strip()}
            (select if opt == "--select" else ignore).update(rids)
        elif a.startswith("-"):
            print(f"deferlint: unknown option {a!r}", file=sys.stderr)
            _usage(file=sys.stderr)
            return 2
        else:
            paths.append(a)
        i += 1
    if not paths:
        _usage(file=sys.stderr)
        return 2
    violations = lint_paths(paths)
    if select:
        violations = [v for v in violations if v.rule in select]
    if ignore:
        violations = [v for v in violations if v.rule not in ignore]
    if as_json:
        print(json.dumps([{"rule": v.rule, "path": v.path, "line": v.line,
                           "message": v.message} for v in violations],
                         indent=2))
    else:
        for v in violations:
            print(v.format())
    if as_github:
        # workflow-command annotations: GitHub renders these inline on the
        # PR diff.  Paths are relative to the lint root's parent, which is
        # the repo root when CI runs `python -m tools.deferlint src ...`.
        for v in violations:
            print(f"::error file={v.path},line={v.line},"
                  f"title=deferlint {v.rule}::{v.message}")
    if violations:
        if not as_json:
            print(f"deferlint: {len(violations)} violation(s)",
                  file=sys.stderr)
        return 1
    if not as_json:
        print("deferlint: clean")
    return 0
